//! Synthesis engine — the Synopsys Design Compiler stand-in (DESIGN.md §1).
//!
//! Composes a gate-level cost estimate for a full accelerator design point
//! from the [`crate::tech`] component models, exactly the quantities the
//! paper extracts from DC + FreePDK45 (§III-C): **area**, **power** (at a
//! reference activity), and **achievable clock**. A seeded multiplicative
//! tool-noise model ([`noise`]) emulates run-to-run synthesis variance so
//! that the polynomial PPA surrogates (Fig. 3) have something non-trivial
//! to fit.

pub mod netlist;
pub mod noise;
pub mod dataset;

pub use dataset::{synthesize_sweep, SynthDataset, SynthRecord};
pub use netlist::{mac_unit, pe_netlist, PeNetlist};

use crate::arch::AcceleratorConfig;
use crate::tech::{self, SramMacro, NODE_45NM};

/// Reference switching activity used for the synthesis power report
/// (fraction of PEs toggling per cycle); matches a mid-utilization layer.
pub const REFERENCE_ACTIVITY: f64 = 0.5;

/// Reference clock for the synthesis power report (GHz). DC reports power
/// at the stated clock constraint, identical across designs, so Fig. 3's
/// power axis compares energy-per-cycle × a common frequency — not each
/// design's achieved frequency.
pub const REFERENCE_CLOCK_GHZ: f64 = 1.0;

/// Area breakdown of a synthesized accelerator (µm²).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AreaBreakdown {
    /// PE array (MACs + scratchpads + local control).
    pub pe_array_um2: f64,
    /// Global buffer macro.
    pub glb_um2: f64,
    /// Network-on-chip wiring and switches.
    pub noc_um2: f64,
    /// Top-level controller.
    pub controller_um2: f64,
}

impl AreaBreakdown {
    /// Total area in µm².
    pub fn total_um2(&self) -> f64 {
        self.pe_array_um2 + self.glb_um2 + self.noc_um2 + self.controller_um2
    }

    /// Total area in mm².
    pub fn total_mm2(&self) -> f64 {
        self.total_um2() / 1e6
    }
}

/// The synthesis "report" for one design point — what DC would print.
#[derive(Debug, Clone, PartialEq)]
pub struct SynthReport {
    /// The synthesized configuration.
    pub config: AcceleratorConfig,
    /// Area breakdown.
    pub area: AreaBreakdown,
    /// Dynamic power at [`REFERENCE_ACTIVITY`] and the achieved clock (mW).
    pub dynamic_power_mw: f64,
    /// Leakage power (mW).
    pub leakage_power_mw: f64,
    /// Maximum achievable clock from the critical path (GHz).
    pub max_clock_ghz: f64,
    /// Clock the design closes timing at: `min(target, achievable)` (GHz).
    pub achieved_clock_ghz: f64,
    /// Per-PE netlist detail (single PE).
    pub pe: PeNetlist,
    /// Global buffer macro.
    pub glb: SramMacro,
}

impl SynthReport {
    /// Total power (mW).
    pub fn total_power_mw(&self) -> f64 {
        self.dynamic_power_mw + self.leakage_power_mw
    }

    /// Peak throughput in GMAC/s at the achieved clock.
    pub fn peak_gmacs(&self) -> f64 {
        self.config.num_pes() as f64 * self.achieved_clock_ghz
    }

    /// Peak performance per area (GMAC/s per mm²) — the paper's headline
    /// efficiency axis.
    pub fn peak_perf_per_area(&self) -> f64 {
        self.peak_gmacs() / self.area.total_mm2()
    }
}

/// Synthesize a design point deterministically (no tool noise) — the
/// "ideal" composition used by unit tests and the energy model.
///
/// # Panics
/// If `config` fails [`AcceleratorConfig::validate`] — callers validate
/// at their API boundary before synthesizing.
#[allow(clippy::expect_used)]
pub fn synthesize_clean(config: &AcceleratorConfig) -> SynthReport {
    config.validate().expect("invalid accelerator config");
    let pe = pe_netlist(config);
    let num_pes = config.num_pes() as f64;

    // Global buffer: banked SRAM macro, 128-bit port.
    let glb = tech::sram::build_sram(config.glb_bytes() * 8, 128);

    // NoC: row/column broadcast buses (Eyeriss-style X/Y buses). Area scales
    // with perimeter × flit width; energy accounted per transfer in the
    // energy model — here it contributes area + leakage only.
    let flit_bits = (config.pe.act_bits().max(config.pe.psum_bits())) as f64;
    let noc_um2 = (config.rows + config.cols) as f64 * flit_bits * 18.0
        + num_pes * flit_bits * 1.1; // per-PE router taps

    let controller = tech::control_logic(64);

    let area = AreaBreakdown {
        pe_array_um2: pe.total.area_um2 * num_pes,
        glb_um2: glb.area_um2,
        noc_um2,
        controller_um2: controller.area_um2,
    };

    // Critical path: MAC datapath vs scratchpad access vs GLB access, plus
    // the array broadcast-bus wire delay (grows with the array perimeter —
    // this is why wide arrays close timing slower in real synthesis runs).
    let wire_ns = 0.0035 * (config.rows + config.cols) as f64;
    let critical_ns = pe
        .critical_path_ns()
        .max(glb.access_ns * 0.9) // GLB is pipelined; 90% of access in one stage
        + wire_ns;
    let max_clock_ghz = 1.0 / critical_ns;
    let achieved_clock_ghz = config.clock_ghz.min(max_clock_ghz);

    // Dynamic power: per-cycle energy of active PEs (MAC + local spad
    // traffic) + amortized GLB traffic, at the reference activity and the
    // reference clock (the DC report convention — see REFERENCE_CLOCK_GHZ).
    let pe_cycle_pj = pe.energy_per_mac_pj();
    let glb_cycle_pj = glb.read_pj * 0.08; // ~1 GLB access / 12 MACs / PE (RS reuse)
    let dynamic_power_mw = REFERENCE_ACTIVITY
        * num_pes
        * (pe_cycle_pj + glb_cycle_pj)
        * REFERENCE_CLOCK_GHZ; // pJ × GHz = mW

    // Leakage: logic area + SRAM macros.
    let logic_area = area.pe_array_um2 * (1.0 - pe.storage_area_fraction())
        + area.noc_um2
        + area.controller_um2;
    let leakage_power_mw = tech::logic_leakage_mw(&NODE_45NM, logic_area)
        + pe.spad_leakage_mw() * num_pes
        + glb.leakage_mw;

    SynthReport {
        config: config.clone(),
        area,
        dynamic_power_mw,
        leakage_power_mw,
        max_clock_ghz,
        achieved_clock_ghz,
        pe,
        glb,
    }
}

/// Synthesize with the tool-noise model applied (the "actual" values of
/// Fig. 3). Deterministic per (config, seed).
pub fn synthesize(config: &AcceleratorConfig, seed: u64) -> SynthReport {
    let mut report = synthesize_clean(config);
    noise::apply(&mut report, seed);
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::SweepSpec;
    use crate::quant::PeType;

    fn cfg(pe: PeType) -> AcceleratorConfig {
        AcceleratorConfig { pe, ..AcceleratorConfig::default() }
    }

    #[test]
    fn area_ordering_matches_paper() {
        // Fig. 3 bottom chart: FP32 highest area, LightPEs lowest.
        let fp32 = synthesize_clean(&cfg(PeType::Fp32));
        let int16 = synthesize_clean(&cfg(PeType::Int16));
        let light1 = synthesize_clean(&cfg(PeType::LightPe1));
        let light2 = synthesize_clean(&cfg(PeType::LightPe2));
        assert!(fp32.area.total_um2() > int16.area.total_um2());
        assert!(int16.area.total_um2() > light2.area.total_um2());
        assert!(light2.area.total_um2() >= light1.area.total_um2());
    }

    #[test]
    fn power_ordering_matches_paper() {
        let fp32 = synthesize_clean(&cfg(PeType::Fp32));
        let int16 = synthesize_clean(&cfg(PeType::Int16));
        let light1 = synthesize_clean(&cfg(PeType::LightPe1));
        assert!(fp32.total_power_mw() > int16.total_power_mw());
        assert!(int16.total_power_mw() > light1.total_power_mw());
    }

    #[test]
    fn lightpe_clocks_faster() {
        // Shift-add datapath is shorter than a 16-bit multiply.
        let int16 = synthesize_clean(&cfg(PeType::Int16));
        let light1 = synthesize_clean(&cfg(PeType::LightPe1));
        assert!(light1.max_clock_ghz > int16.max_clock_ghz);
    }

    #[test]
    fn achieved_clock_capped_by_target() {
        let report = synthesize_clean(&cfg(PeType::LightPe1));
        assert!(report.achieved_clock_ghz <= report.config.clock_ghz + 1e-12);
        assert!(report.achieved_clock_ghz <= report.max_clock_ghz + 1e-12);
    }

    #[test]
    fn area_scales_with_array() {
        let small = synthesize_clean(&AcceleratorConfig { rows: 8, cols: 8, ..cfg(PeType::Int16) });
        let big =
            synthesize_clean(&AcceleratorConfig { rows: 32, cols: 32, ..cfg(PeType::Int16) });
        let ratio = big.area.pe_array_um2 / small.area.pe_array_um2;
        assert!((ratio - 16.0).abs() < 1e-6, "PE array area must scale ×16, got {ratio}");
    }

    #[test]
    fn noise_is_deterministic_and_small() {
        let config = cfg(PeType::Int16);
        let a = synthesize(&config, 7);
        let b = synthesize(&config, 7);
        assert_eq!(a.area.total_um2(), b.area.total_um2());
        let clean = synthesize_clean(&config);
        let rel = crate::util::rel_diff(a.area.total_um2(), clean.area.total_um2());
        assert!(rel < 0.25, "noise should be bounded, got {rel}");
        // Different seed → different noise.
        let c = synthesize(&config, 8);
        assert_ne!(a.area.total_um2(), c.area.total_um2());
    }

    #[test]
    fn perf_per_area_spread_covers_paper_range() {
        // Fig. 2: >5× spread in perf/area across the space.
        let reports: Vec<SynthReport> =
            SweepSpec::default().enumerate().iter().map(synthesize_clean).collect();
        let ppa: Vec<f64> = reports.iter().map(|r| r.peak_perf_per_area()).collect();
        let spread = crate::util::stats::max(&ppa) / crate::util::stats::min(&ppa);
        assert!(spread > 5.0, "peak perf/area spread {spread} must exceed 5×");
    }

    #[test]
    fn glb_dominates_at_large_buffer_small_array() {
        let report = synthesize_clean(&AcceleratorConfig {
            rows: 8,
            cols: 8,
            glb_kib: 512,
            ..cfg(PeType::LightPe1)
        });
        assert!(report.area.glb_um2 > report.area.pe_array_um2);
    }
}
