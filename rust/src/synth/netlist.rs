//! PE netlist composition: MAC datapaths and scratchpads per PE type.
//!
//! Mirrors the paper's §III-A PE microarchitecture: each PE holds an ifmap
//! scratchpad, a filter scratchpad, a psum scratchpad, and a MAC unit that
//! is either a conventional multiplier+adder (FP32, INT16) or a shift-add
//! unit (LightPE-1/2, §III-B).

use crate::arch::AcceleratorConfig;
use crate::quant::PeType;
use crate::tech::{self, Component, SramMacro, NODE_45NM};

/// The MAC datapath for a PE type, as a composed [`Component`].
///
/// The multiply and accumulate halves are **pipelined** (a register between
/// them, as DC would retime a 2-stage MAC), so the critical path is the
/// *longest stage*, not the full chain — areas and energies still sum.
///
/// * FP32: fp32 multiplier ‖ fp32 adder stages.
/// * INT16: 16×16 multiplier ‖ 48-bit accumulate adder.
/// * LightPE-1: sign unit → one barrel shift (act 8b shifted into 16b)
///   ‖ 24-bit accumulate adder.
/// * LightPE-2: two parallel barrel shifts → 16-bit combine adder →
///   sign unit ‖ 24-bit accumulate adder.
pub fn mac_unit(pe: PeType) -> Component {
    let pipeline = |stage1: Component, stage2: Component, width: u32| {
        let reg = tech::register(width);
        Component {
            area_um2: stage1.area_um2 + stage2.area_um2 + reg.area_um2,
            energy_pj: stage1.energy_pj + stage2.energy_pj + reg.energy_pj,
            delay_ns: stage1.delay_ns.max(stage2.delay_ns) + reg.delay_ns,
        }
    };
    match pe {
        PeType::Fp32 => pipeline(tech::fp_multiplier(32), tech::fp_adder(32), 32),
        PeType::Int16 => pipeline(
            tech::int_multiplier(16),
            tech::int_adder(PeType::Int16.psum_bits()),
            PeType::Int16.psum_bits(),
        ),
        PeType::LightPe1 => pipeline(
            tech::sign_unit(16).then(tech::barrel_shifter(16, 3)),
            tech::int_adder(PeType::LightPe1.psum_bits()),
            PeType::LightPe1.psum_bits(),
        ),
        PeType::LightPe2 => pipeline(
            tech::barrel_shifter(16, 3)
                .plus(tech::barrel_shifter(16, 3))
                .then(tech::int_adder(16))
                .then(tech::sign_unit(16)),
            tech::int_adder(PeType::LightPe2.psum_bits()),
            PeType::LightPe2.psum_bits(),
        ),
    }
}

/// A fully composed PE: MAC + three scratchpads + local control.
#[derive(Debug, Clone, PartialEq)]
pub struct PeNetlist {
    /// PE type the netlist implements.
    pub pe_type: PeType,
    /// The MAC datapath (multiplier or shift-add).
    pub mac: Component,
    /// Input-feature-map scratchpad.
    pub ifmap_spad: SramMacro,
    /// Filter-weight scratchpad.
    pub filter_spad: SramMacro,
    /// Partial-sum scratchpad.
    pub psum_spad: SramMacro,
    /// Local control logic.
    pub control: Component,
    /// Aggregate component (areas summed; delay = datapath critical path).
    pub total: Component,
}

/// Compose the PE netlist for a configuration.
pub fn pe_netlist(config: &AcceleratorConfig) -> PeNetlist {
    let pe = config.pe;
    let mac = mac_unit(pe);
    let spad = &config.spad;
    // PE scratchpads synthesize to register files (Eyeriss-style), keeping
    // area/energy monotone in bit width across PE types.
    let ifmap_spad = tech::sram::build_regfile(
        spad.ifmap_entries * pe.act_bits() as usize,
        pe.act_bits() as usize,
    );
    let filter_spad = tech::sram::build_regfile(
        spad.filter_entries * pe.weight_bits() as usize,
        pe.weight_bits() as usize,
    );
    let psum_spad = tech::sram::build_regfile(
        spad.psum_entries * pe.psum_bits() as usize,
        pe.psum_bits() as usize,
    );
    let control = tech::control_logic(16);
    let total = Component {
        area_um2: mac.area_um2
            + ifmap_spad.area_um2
            + filter_spad.area_um2
            + psum_spad.area_um2
            + control.area_um2,
        energy_pj: 0.0, // energy accounted per-access, not as a lump
        delay_ns: mac.delay_ns,
    };
    PeNetlist { pe_type: pe, mac, ifmap_spad, filter_spad, psum_spad, control, total }
}

impl PeNetlist {
    /// Critical path through the PE (ns): spad read → MAC → psum write.
    pub fn critical_path_ns(&self) -> f64 {
        // Reads are pipelined with compute; the longer of (spad access) and
        // (MAC datapath) sets the stage time.
        let spad_ns = self
            .ifmap_spad
            .access_ns
            .max(self.filter_spad.access_ns)
            .max(self.psum_spad.access_ns);
        self.mac.delay_ns.max(spad_ns)
    }

    /// Energy of one MAC *including* the local scratchpad traffic it
    /// implies under row-stationary reuse: one ifmap read, one filter read,
    /// one psum read + write per MAC (psum is read-modify-write).
    pub fn energy_per_mac_pj(&self) -> f64 {
        self.mac.energy_pj
            + self.ifmap_spad.read_pj
            + self.filter_spad.read_pj
            + self.psum_spad.read_pj
            + self.psum_spad.write_pj
    }

    /// Fraction of PE area that is storage (used to split leakage between
    /// the logic and SRAM models).
    pub fn storage_area_fraction(&self) -> f64 {
        let storage =
            self.ifmap_spad.area_um2 + self.filter_spad.area_um2 + self.psum_spad.area_um2;
        storage / self.total.area_um2
    }

    /// Scratchpad leakage for one PE (mW).
    pub fn spad_leakage_mw(&self) -> f64 {
        self.ifmap_spad.leakage_mw(&NODE_45NM)
            + self.filter_spad.leakage_mw(&NODE_45NM)
            + self.psum_spad.leakage_mw(&NODE_45NM)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ScratchpadCfg;

    #[test]
    fn mac_area_ordering() {
        let fp32 = mac_unit(PeType::Fp32);
        let int16 = mac_unit(PeType::Int16);
        let light1 = mac_unit(PeType::LightPe1);
        let light2 = mac_unit(PeType::LightPe2);
        assert!(fp32.area_um2 > int16.area_um2);
        assert!(int16.area_um2 > light2.area_um2);
        assert!(light2.area_um2 > light1.area_um2);
    }

    #[test]
    fn mac_energy_ordering() {
        let fp32 = mac_unit(PeType::Fp32);
        let int16 = mac_unit(PeType::Int16);
        let light1 = mac_unit(PeType::LightPe1);
        assert!(fp32.energy_pj > int16.energy_pj);
        assert!(int16.energy_pj > 3.0 * light1.energy_pj, "shift-add must be ≫ cheaper");
    }

    #[test]
    fn shift_add_shorter_critical_path() {
        assert!(mac_unit(PeType::LightPe1).delay_ns < mac_unit(PeType::Int16).delay_ns);
        assert!(mac_unit(PeType::Int16).delay_ns < mac_unit(PeType::Fp32).delay_ns);
    }

    #[test]
    fn pe_netlist_spads_scale_with_bits() {
        let mk = |pe| {
            pe_netlist(&AcceleratorConfig { pe, ..AcceleratorConfig::default() })
        };
        let int16 = mk(PeType::Int16);
        let light1 = mk(PeType::LightPe1);
        assert!(int16.filter_spad.area_um2 > light1.filter_spad.area_um2);
        assert!(int16.ifmap_spad.area_um2 > light1.ifmap_spad.area_um2);
    }

    #[test]
    fn energy_per_mac_includes_spads() {
        let net = pe_netlist(&AcceleratorConfig::default());
        assert!(net.energy_per_mac_pj() > net.mac.energy_pj);
    }

    #[test]
    fn storage_fraction_in_unit_interval() {
        for pe in PeType::ALL {
            let net = pe_netlist(&AcceleratorConfig { pe, ..AcceleratorConfig::default() });
            let f = net.storage_area_fraction();
            assert!(f > 0.0 && f < 1.0, "{pe}: storage fraction {f}");
        }
    }

    #[test]
    fn bigger_spads_bigger_pe() {
        let small = pe_netlist(&AcceleratorConfig {
            spad: ScratchpadCfg { ifmap_entries: 12, filter_entries: 112, psum_entries: 16 },
            ..AcceleratorConfig::default()
        });
        let large = pe_netlist(&AcceleratorConfig {
            spad: ScratchpadCfg { ifmap_entries: 24, filter_entries: 448, psum_entries: 32 },
            ..AcceleratorConfig::default()
        });
        assert!(large.total.area_um2 > small.total.area_um2);
    }

    #[test]
    fn critical_path_at_least_mac_delay() {
        for pe in PeType::ALL {
            let net = pe_netlist(&AcceleratorConfig { pe, ..AcceleratorConfig::default() });
            assert!(net.critical_path_ns() >= net.mac.delay_ns);
        }
    }
}
