//! Synthesis dataset generation for PPA model fitting (§III-C).
//!
//! The paper runs DC over the swept design space and fits polynomial
//! regression models to the resulting (config → power/perf/area) samples.
//! [`synthesize_sweep`] is that data-collection loop over our synthesis
//! engine; the output feeds [`crate::ppa`].

use super::{synthesize, SynthReport};
use crate::arch::{AcceleratorConfig, SweepSpec};
use crate::quant::PeType;

/// One (design point → synthesis results) sample.
#[derive(Debug, Clone)]
pub struct SynthRecord {
    /// The synthesized design point.
    pub config: AcceleratorConfig,
    /// Total area (mm²).
    pub area_mm2: f64,
    /// Total power (mW) at the reference activity.
    pub power_mw: f64,
    /// Achievable clock (GHz) — the "performance" axis of Fig. 3 (per-PE
    /// performance is clock × 1 MAC/cycle).
    pub max_clock_ghz: f64,
}

impl SynthRecord {
    /// Build from a synthesis report.
    pub fn from_report(report: &SynthReport) -> Self {
        Self {
            config: report.config.clone(),
            area_mm2: report.area.total_mm2(),
            power_mw: report.total_power_mw(),
            max_clock_ghz: report.max_clock_ghz,
        }
    }
}

/// A labeled synthesis dataset for one PE type (Fig. 3 fits each PE type
/// separately).
#[derive(Debug, Clone)]
pub struct SynthDataset {
    /// PE type every record shares.
    pub pe: PeType,
    /// One record per synthesized design point.
    pub records: Vec<SynthRecord>,
}

impl SynthDataset {
    /// Observation vector for a named target metric.
    ///
    /// # Panics
    /// On a metric name other than `area` / `power` / `perf` — callers
    /// iterate exactly that fixed set.
    #[allow(clippy::panic)]
    pub fn targets(&self, metric: &str) -> Vec<f64> {
        self.records
            .iter()
            .map(|r| match metric {
                "area" => r.area_mm2,
                "power" => r.power_mw,
                "perf" => r.max_clock_ghz,
                other => panic!("unknown metric '{other}'"),
            })
            .collect()
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Whether the dataset is empty.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }
}

/// Run the synthesis engine over every design point of `spec` restricted to
/// `pe`, with tool noise keyed by `seed`.
pub fn synthesize_sweep(spec: &SweepSpec, pe: PeType, seed: u64) -> SynthDataset {
    let records = spec
        .clone()
        .for_pe(pe)
        .enumerate()
        .iter()
        .map(|config| SynthRecord::from_report(&synthesize(config, seed)))
        .collect();
    SynthDataset { pe, records }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_space() {
        let spec = SweepSpec::tiny();
        let ds = synthesize_sweep(&spec, PeType::Int16, 1);
        assert_eq!(ds.len(), spec.clone().for_pe(PeType::Int16).len());
        assert!(ds.records.iter().all(|r| r.config.pe == PeType::Int16));
    }

    #[test]
    fn targets_extract_metrics() {
        let ds = synthesize_sweep(&SweepSpec::tiny(), PeType::Int16, 1);
        for metric in ["area", "power", "perf"] {
            let ys = ds.targets(metric);
            assert_eq!(ys.len(), ds.len());
            assert!(ys.iter().all(|&y| y > 0.0), "{metric} must be positive");
        }
    }

    #[test]
    #[should_panic(expected = "unknown metric")]
    fn unknown_metric_panics() {
        let ds = synthesize_sweep(&SweepSpec::tiny(), PeType::Int16, 1);
        ds.targets("latency");
    }

    #[test]
    fn dataset_deterministic_per_seed() {
        let a = synthesize_sweep(&SweepSpec::tiny(), PeType::LightPe1, 9);
        let b = synthesize_sweep(&SweepSpec::tiny(), PeType::LightPe1, 9);
        assert_eq!(a.targets("area"), b.targets("area"));
    }
}
