//! Synthesis tool-noise model.
//!
//! Real synthesis runs are not a smooth function of the RTL parameters:
//! placement seeds, mapping heuristics, and timing-closure effort inject
//! run-to-run variance, and corner configurations synthesize slightly off
//! the trend (e.g. very wide arrays route worse). The paper's Fig. 3 fits
//! polynomial models *to that noisy data*; this module reproduces the
//! noise so the fit quality numbers are meaningful rather than exact.
//!
//! Noise is **deterministic** per (config, seed): the stream is keyed by a
//! hash of the config id, so re-"synthesizing" the same design reproduces
//! the same report, exactly like re-running DC with the same seed.

use super::SynthReport;
use crate::util::rng::Pcg64;

/// Multiplicative noise sigma for area (lognormal).
pub const AREA_SIGMA: f64 = 0.03;
/// Multiplicative noise sigma for power.
pub const POWER_SIGMA: f64 = 0.05;
/// Multiplicative noise sigma for the achievable clock.
pub const CLOCK_SIGMA: f64 = 0.015;

/// FNV-1a hash of the config id (stable across runs and platforms).
fn fnv1a(text: &str) -> u64 {
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    for byte in text.bytes() {
        hash ^= byte as u64;
        hash = hash.wrapping_mul(0x1000_0000_01b3);
    }
    hash
}

/// Apply tool noise to a clean report in place.
pub fn apply(report: &mut SynthReport, seed: u64) {
    let key = fnv1a(&report.config.id()) ^ seed.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    let mut rng = Pcg64::with_stream(key, seed);

    // Systematic effects first (they bias, not just scatter):
    // 1. Routing congestion penalty for very wide arrays — wirelength grows
    //    superlinearly, DC pads the array area.
    let pes = report.config.num_pes() as f64;
    let congestion = 1.0 + 0.015 * (pes / 256.0).max(1.0).ln();
    // 2. Large GLB macros close timing slightly worse (longer wires to the
    //    array edge), costing clock.
    let glb_penalty = 1.0 - 0.01 * (report.config.glb_kib as f64 / 128.0).max(1.0).ln();

    let area_factor = congestion * rng.lognormal(0.0, AREA_SIGMA);
    let power_factor = rng.lognormal(0.0, POWER_SIGMA);
    let clock_factor = glb_penalty * rng.lognormal(0.0, CLOCK_SIGMA);

    report.area.pe_array_um2 *= area_factor;
    report.area.noc_um2 *= area_factor;
    report.area.glb_um2 *= rng.lognormal(0.0, AREA_SIGMA * 0.5); // macros vary less
    report.dynamic_power_mw *= power_factor;
    report.leakage_power_mw *= rng.lognormal(0.0, POWER_SIGMA * 0.6);
    report.max_clock_ghz *= clock_factor;
    report.achieved_clock_ghz = report.config.clock_ghz.min(report.max_clock_ghz);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::AcceleratorConfig;
    use crate::synth::synthesize_clean;
    use crate::util::stats;

    #[test]
    fn deterministic_per_config_and_seed() {
        let config = AcceleratorConfig::default();
        let mut a = synthesize_clean(&config);
        let mut b = synthesize_clean(&config);
        apply(&mut a, 42);
        apply(&mut b, 42);
        assert_eq!(a.area.total_um2(), b.area.total_um2());
        assert_eq!(a.dynamic_power_mw, b.dynamic_power_mw);
    }

    #[test]
    fn different_seeds_differ() {
        let config = AcceleratorConfig::default();
        let mut a = synthesize_clean(&config);
        let mut b = synthesize_clean(&config);
        apply(&mut a, 1);
        apply(&mut b, 2);
        assert_ne!(a.area.total_um2(), b.area.total_um2());
    }

    #[test]
    fn noise_unbiased_and_bounded() {
        let config = AcceleratorConfig::default();
        let clean = synthesize_clean(&config).area.total_um2();
        let ratios: Vec<f64> = (0..200)
            .map(|seed| {
                let mut r = synthesize_clean(&config);
                apply(&mut r, seed);
                r.area.total_um2() / clean
            })
            .collect();
        let mean = stats::mean(&ratios);
        // Mean within a few % of the (slightly >1, congestion-biased) center.
        assert!(mean > 0.97 && mean < 1.10, "mean ratio {mean}");
        assert!(stats::max(&ratios) < 1.25);
        assert!(stats::min(&ratios) > 0.8);
    }

    #[test]
    fn achieved_clock_stays_consistent() {
        let config = AcceleratorConfig::default();
        for seed in 0..50 {
            let mut r = synthesize_clean(&config);
            apply(&mut r, seed);
            assert!(r.achieved_clock_ghz <= r.max_clock_ghz + 1e-12);
            assert!(r.achieved_clock_ghz <= r.config.clock_ghz + 1e-12);
        }
    }

    #[test]
    fn congestion_biases_large_arrays_up() {
        let small = AcceleratorConfig { rows: 8, cols: 8, ..AcceleratorConfig::default() };
        let large = AcceleratorConfig { rows: 32, cols: 32, ..AcceleratorConfig::default() };
        let bias = |config: &AcceleratorConfig| {
            let clean = synthesize_clean(config).area.pe_array_um2;
            let noisy: Vec<f64> = (0..100)
                .map(|seed| {
                    let mut r = synthesize_clean(config);
                    apply(&mut r, seed);
                    r.area.pe_array_um2 / clean
                })
                .collect();
            stats::mean(&noisy)
        };
        assert!(bias(&large) > bias(&small), "large arrays must synthesize with more overhead");
    }
}
