//! Static analysis over resolved campaigns — `qadam lint`.
//!
//! The resolver ([`super::resolve`]) rejects specs that are *invalid*;
//! this pass flags specs that are valid but *mis-specified*: budgets
//! that silently degrade to exhaustive walks, scratchpads too small for
//! any swept layer, accuracy declarations that are never consulted,
//! persist plans that will collide with on-disk artifacts at runtime.
//! Every rule is purely static — no design point is ever evaluated —
//! so linting a million-point campaign costs milliseconds.
//!
//! Rules live in a fixed [`RULES`] registry with stable codes (`Q001`…)
//! and a default severity ([`Level`]); `--deny`/`--allow` selectors
//! re-level or suppress them per run. Findings carry source spans
//! resolved against the spec AST and render through the standard
//! [`Diagnostics`] pipeline (file:line:col, excerpt, caret, help), or
//! as a JSON document for CI via [`to_json`].
//!
//! ```
//! use qadam::spec::lint::{self, LintOptions};
//!
//! let source = "sweep {\n  pe_type = [int16]\n  array = [8x8]\n}\n\
//!               strategy = random(99)\n";
//! let (campaign, diags, findings) = lint::lint_source(source, &LintOptions::default());
//! assert!(campaign.is_some() && !diags.has_errors());
//! // random(99) covers the whole 48-point space (the unset axes keep
//! // their defaults): the sampling degrades to an exhaustive walk.
//! assert_eq!(findings.len(), 1);
//! assert_eq!(findings[0].code, "Q002");
//! ```

use std::collections::BTreeSet;

use crate::arch::{DesignSpace, ModelVariant};
use crate::dnn::{scale_model, Layer, LayerKind, Model};
use crate::error::{Error, Result};
use crate::explore::persist::CampaignManifest;
use crate::util::json::{num, obj, s, Json};

use super::ast::{Block, KeyValue, LayerStmt, ModelBlock, ModelStmt, Section, SpecFile, ValueKind};
use super::diag::{locate, Diagnostics, Span};
use super::resolve::{pe_key, ResolvedCampaign, StrategyChoice, WorkloadModel};

/// Severity of a lint finding.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Advisory: the campaign runs, but probably not as intended.
    Warn,
    /// The campaign is degenerate or will fail/collide at runtime;
    /// `qadam lint` exits nonzero when any deny-level finding survives.
    Deny,
}

impl Level {
    /// Lowercase label used by selectors and the JSON output.
    pub fn label(self) -> &'static str {
        match self {
            Level::Warn => "warn",
            Level::Deny => "deny",
        }
    }
}

/// One diagnostic produced by a lint rule, tagged with its rule code.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Stable rule code (`"Q001"` …) — safe to pin in CI configs.
    pub code: &'static str,
    /// Human-readable rule name (`"dead-axis-value"` …).
    pub name: &'static str,
    /// Effective severity after `--deny`/`--allow` overrides.
    pub level: Level,
    /// Source span the finding anchors to (`Span::at(0)` when the
    /// construct was defaulted and has no spelling in the source).
    pub span: Span,
    /// What is mis-specified, phrased against the source text.
    pub message: String,
    /// Optional fix-it line.
    pub help: Option<String>,
}

/// A rule's draft finding before the registry stamps code/name/level.
struct Draft {
    span: Span,
    message: String,
    help: Option<String>,
    /// Rules that grade their own findings (e.g. [Q004]) override the
    /// registry default here.
    level: Option<Level>,
}

impl Draft {
    fn new(span: Span, message: String, help: String) -> Self {
        Self { span, message, help: Some(help), level: None }
    }

    fn leveled(span: Span, message: String, help: String, level: Level) -> Self {
        Self { span, message, help: Some(help), level: Some(level) }
    }
}

/// Everything a rule may inspect: the source text (for excerpts), the
/// spanned AST (for locations), and the resolved campaign (for
/// semantics). Rules never mutate and never evaluate design points.
struct LintContext<'a> {
    source: &'a str,
    file: &'a SpecFile,
    campaign: &'a ResolvedCampaign,
}

/// One entry of the static [`RULES`] registry.
pub struct LintRule {
    /// Stable code, `Q` + three digits, never reused.
    pub code: &'static str,
    /// Kebab-case rule name (an alias for the code in selectors).
    pub name: &'static str,
    /// One-line description (the DESIGN.md rule table mirrors these).
    pub summary: &'static str,
    /// Default severity, before `--deny`/`--allow` overrides.
    pub level: Level,
    check: fn(&LintContext<'_>) -> Vec<Draft>,
}

/// The rule registry, in code order. Codes are append-only: a retired
/// rule's code is never reassigned, so CI `--deny Qnnn` pins stay valid.
pub const RULES: &[LintRule] = &[
    LintRule {
        code: "Q001",
        name: "dead-axis-value",
        summary: "duplicate sweep-axis values or a no-op model_axes block",
        level: Level::Warn,
        check: dead_axis_value,
    },
    LintRule {
        code: "Q002",
        name: "budget-covers-space",
        summary: "strategy budget >= the (sharded) space: degrades to exhaustive",
        level: Level::Warn,
        check: budget_covers_space,
    },
    LintRule {
        code: "Q003",
        name: "halving-rounds-excess",
        summary: "halving pool converges early: trailing rounds never run, final ranking is low-fidelity",
        level: Level::Warn,
        check: halving_rounds_excess,
    },
    LintRule {
        code: "Q004",
        name: "spad-insufficient",
        summary: "scratchpad cannot hold one kernel row of a swept model's layer",
        level: Level::Warn,
        check: spad_insufficient,
    },
    LintRule {
        code: "Q005",
        name: "glb-below-working-set",
        summary: "GLB smaller than every layer's ifmap: each layer refetches from DRAM",
        level: Level::Warn,
        check: glb_below_working_set,
    },
    LintRule {
        code: "Q006",
        name: "accuracy-unswept-precision",
        summary: "accuracy declared for a precision the sweep never evaluates",
        level: Level::Warn,
        check: accuracy_unswept_precision,
    },
    LintRule {
        code: "Q007",
        name: "shadowed-override",
        summary: "a like-model overrides the same layer twice",
        level: Level::Warn,
        check: shadowed_override,
    },
    LintRule {
        code: "Q008",
        name: "layer-chain-mismatch",
        summary: "consecutive custom-model layers have incompatible geometry",
        level: Level::Deny,
        check: layer_chain_mismatch,
    },
    LintRule {
        code: "Q009",
        name: "collapsed-variants",
        summary: "model_axes variants lower to identical layer stacks",
        level: Level::Warn,
        check: collapsed_variants,
    },
    LintRule {
        code: "Q010",
        name: "persist-hazard",
        summary: "checkpoint without an explicit flush interval, or frontier without db",
        level: Level::Warn,
        check: persist_hazard,
    },
    LintRule {
        code: "Q011",
        name: "resume-mismatch",
        summary: "existing on-disk artifact is incompatible with this campaign",
        level: Level::Deny,
        check: resume_mismatch,
    },
    LintRule {
        code: "Q012",
        name: "empty-selection",
        summary: "the sharded campaign selects zero design points",
        level: Level::Deny,
        check: empty_selection,
    },
];

/// Per-run rule overrides, parsed from `--deny` / `--allow` selectors.
/// `allow` wins over `deny`; either accepts rule codes (`Q004`), rule
/// names (`spad-insufficient`), or the keyword `all`.
#[derive(Debug, Clone, Default)]
pub struct LintOptions {
    deny_all: bool,
    allow_all: bool,
    deny: BTreeSet<&'static str>,
    allow: BTreeSet<&'static str>,
}

impl LintOptions {
    /// Parse comma-separated `--deny` / `--allow` selector lists; empty
    /// strings select nothing. Unknown selectors are a typed error
    /// listing the valid codes.
    pub fn parse(deny: &str, allow: &str) -> Result<Self> {
        let mut opts = LintOptions::default();
        let (deny_all, deny_set) = parse_selector(deny)?;
        let (allow_all, allow_set) = parse_selector(allow)?;
        opts.deny_all = deny_all;
        opts.allow_all = allow_all;
        opts.deny = deny_set;
        opts.allow = allow_set;
        Ok(opts)
    }

    /// Whether a rule is suppressed outright.
    fn allowed(&self, code: &str) -> bool {
        self.allow_all || self.allow.contains(code)
    }

    /// Whether a rule's findings are escalated to [`Level::Deny`].
    fn denied(&self, code: &str) -> bool {
        self.deny_all || self.deny.contains(code)
    }
}

/// Resolve one selector list to `(all, codes)`.
fn parse_selector(text: &str) -> Result<(bool, BTreeSet<&'static str>)> {
    let mut all = false;
    let mut codes = BTreeSet::new();
    for part in text.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        if part.eq_ignore_ascii_case("all") {
            all = true;
            continue;
        }
        let rule = RULES
            .iter()
            .find(|r| r.code.eq_ignore_ascii_case(part) || r.name == part)
            .ok_or_else(|| {
                Error::InvalidConfig(format!(
                    "unknown lint rule '{part}' (rules are {} through {}, or names like '{}')",
                    RULES[0].code,
                    RULES[RULES.len() - 1].code,
                    RULES[0].name
                ))
            })?;
        codes.insert(rule.code);
    }
    Ok((all, codes))
}

/// Run every registered rule over a resolved campaign. Findings are
/// deterministically ordered by `(span.start, span.end, code)` — the
/// order is a stable part of the output contract (golden-tested).
pub fn lint_campaign(
    source: &str,
    file: &SpecFile,
    campaign: &ResolvedCampaign,
    opts: &LintOptions,
) -> Vec<Finding> {
    let ctx = LintContext { source, file, campaign };
    let mut findings = Vec::new();
    for rule in RULES {
        if opts.allowed(rule.code) {
            continue;
        }
        for draft in (rule.check)(&ctx) {
            let level = if opts.denied(rule.code) {
                Level::Deny
            } else {
                draft.level.unwrap_or(rule.level)
            };
            findings.push(Finding {
                code: rule.code,
                name: rule.name,
                level,
                span: draft.span,
                message: draft.message,
                help: draft.help,
            });
        }
    }
    findings.sort_by(|a, b| {
        (a.span.start, a.span.end, a.code).cmp(&(b.span.start, b.span.end, b.code))
    });
    findings
}

/// Parse, resolve, and lint a spec source in one shot — the `qadam
/// lint` entry point. Resolver diagnostics come back untouched; the
/// findings are empty whenever the spec does not resolve (lint rules
/// only ever see valid campaigns).
pub fn lint_source(
    source: &str,
    opts: &LintOptions,
) -> (Option<ResolvedCampaign>, Diagnostics, Vec<Finding>) {
    let mut diags = Diagnostics::new();
    let file = super::parser::parse(source, &mut diags);
    let campaign = super::resolve::resolve(&file, &mut diags);
    let findings = match &campaign {
        Some(campaign) => lint_campaign(source, &file, campaign, opts),
        None => Vec::new(),
    };
    (campaign, diags, findings)
}

/// Lower findings into the standard diagnostics batch (deny → error,
/// warn → warning) with `[Qnnn]`-prefixed messages, ready for
/// [`Diagnostics::render`].
pub fn to_diagnostics(findings: &[Finding]) -> Diagnostics {
    let mut out = Diagnostics::new();
    for finding in findings {
        let message = format!("[{}] {}", finding.code, finding.message);
        match (finding.level, &finding.help) {
            (Level::Deny, Some(help)) => out.error_help(finding.span, message, help.clone()),
            (Level::Deny, None) => out.error(finding.span, message),
            (Level::Warn, Some(help)) => out.warn_help(finding.span, message, help.clone()),
            (Level::Warn, None) => out.warn(finding.span, message),
        }
    }
    out
}

/// Render findings rustc-style against their source (excerpt, caret,
/// help), byte-deterministic for golden tests.
pub fn render(findings: &[Finding], source: &str, filename: &str) -> String {
    to_diagnostics(findings).render(source, filename)
}

/// The machine-readable `--format json` document for one linted file:
/// `{"kind": "qadam.lint", "schema": 1, ...}` with per-finding
/// line/column coordinates matching the text renderer. Round-trips
/// through [`Json::parse`].
pub fn to_json(filename: &str, source: &str, findings: &[Finding]) -> Json {
    let rendered: Vec<Json> = findings
        .iter()
        .map(|finding| {
            let (line, col) = locate(source, finding.span.start);
            let mut fields = vec![
                ("code", s(finding.code)),
                ("rule", s(finding.name)),
                ("level", s(finding.level.label())),
                ("line", num(line as f64)),
                ("col", num(col as f64)),
                ("start", num(finding.span.start as f64)),
                ("end", num(finding.span.end as f64)),
                ("message", s(&finding.message)),
            ];
            if let Some(help) = &finding.help {
                fields.push(("help", s(help)));
            }
            obj(fields)
        })
        .collect();
    let denials = findings.iter().filter(|f| f.level == Level::Deny).count();
    obj(vec![
        ("kind", s("qadam.lint")),
        ("schema", num(1.0)),
        ("file", s(filename)),
        ("findings", Json::Arr(rendered)),
        ("warn_count", num((findings.len() - denials) as f64)),
        ("deny_count", num(denials as f64)),
    ])
}

// --- AST span lookup -----------------------------------------------------
//
// The resolver deliberately discards spans when lowering; rules walk the
// AST to re-anchor their findings. Defaulted constructs (no spelling in
// the source) fall back to `Span::at(0)` — the top of the file.

fn sweep_block(file: &SpecFile) -> Option<&Block> {
    file.sections.iter().find_map(|section| match section {
        Section::Sweep(block) => Some(block),
        _ => None,
    })
}

fn campaign_block(file: &SpecFile) -> Option<&Block> {
    file.sections.iter().find_map(|section| match section {
        Section::Campaign(block) => Some(block),
        _ => None,
    })
}

fn model_axes_block(file: &SpecFile) -> Option<&Block> {
    file.sections.iter().find_map(|section| match section {
        Section::ModelAxes(block) => Some(block),
        _ => None,
    })
}

fn persist_block(file: &SpecFile) -> Option<&Block> {
    file.sections.iter().find_map(|section| match section {
        Section::Persist(block) => Some(block),
        _ => None,
    })
}

fn strategy_span(file: &SpecFile) -> Option<Span> {
    file.sections.iter().find_map(|section| match section {
        Section::Strategy(decl) => Some(decl.value.span),
        _ => None,
    })
}

fn model_block<'a>(file: &'a SpecFile, name: &str) -> Option<&'a ModelBlock> {
    file.sections.iter().find_map(|section| match section {
        Section::Model(block) if block.name.node == name => Some(block),
        _ => None,
    })
}

fn entry<'a>(block: &'a Block, key: &str) -> Option<&'a KeyValue> {
    block.entries.iter().find(|kv| kv.key.node == key)
}

fn entry_span(block: Option<&Block>, key: &str) -> Option<Span> {
    entry(block?, key).map(|kv| kv.key.span)
}

/// Span of item `index` of a list-valued entry, when the source spells
/// the list out (the resolver guarantees index alignment for campaigns
/// that resolved without errors).
fn list_item_span(block: Option<&Block>, key: &str, index: usize) -> Option<Span> {
    let kv = entry(block?, key)?;
    match &kv.value.kind {
        ValueKind::List(items) => items.get(index).map(|v| v.span),
        _ => None,
    }
}

fn layer_stmts(block: &ModelBlock) -> Vec<&LayerStmt> {
    block
        .stmts
        .iter()
        .filter_map(|stmt| match stmt {
            ModelStmt::Layer(layer) => Some(layer),
            _ => None,
        })
        .collect()
}

fn or_top(span: Option<Span>) -> Span {
    span.unwrap_or(Span::at(0))
}

/// The verbatim source text a span covers (for quoting values back).
fn excerpt<'a>(source: &'a str, span: Span) -> &'a str {
    source.get(span.start..span.end.min(source.len())).unwrap_or("")
}

/// Design points this shard walks: `ceil((len - shard) / num_shards)`
/// of the joint space — the same arithmetic the Explorer uses.
fn shard_positions(campaign: &ResolvedCampaign) -> usize {
    let len = campaign.sweep.len() * campaign.model_axes.len();
    let (shard, num_shards) = campaign.shard;
    if num_shards == 0 || shard >= len {
        0
    } else {
        (len - shard).div_ceil(num_shards)
    }
}

// --- Rules ---------------------------------------------------------------

/// Q001: a sweep axis that repeats a value multiplies the space with
/// byte-identical configurations; an explicit `model_axes` block that
/// only declares the identity variant is a no-op.
fn dead_axis_value(ctx: &LintContext<'_>) -> Vec<Draft> {
    let mut out = Vec::new();
    let sweep = sweep_block(ctx.file);
    let campaign = ctx.campaign;

    fn duplicate_indices<T: PartialEq>(values: &[T]) -> Vec<usize> {
        (0..values.len()).filter(|&i| values[..i].contains(&values[i])).collect()
    }

    let per_axis: [(&str, Vec<usize>); 6] = [
        ("pe_type", duplicate_indices(&campaign.sweep.pe_types)),
        ("array", duplicate_indices(&campaign.sweep.array_dims)),
        ("glb_kib", duplicate_indices(&campaign.sweep.glb_kib)),
        ("spad", duplicate_indices(&campaign.sweep.spads)),
        ("dram_gbps", duplicate_indices(&campaign.sweep.dram_bw_gbps)),
        ("clock_ghz", duplicate_indices(&campaign.sweep.clock_ghz)),
    ];
    for (key, indices) in per_axis {
        for index in indices {
            let span = or_top(list_item_span(sweep, key, index).or(entry_span(sweep, key)));
            let text = excerpt(ctx.source, span);
            out.push(Draft::new(
                span,
                format!(
                    "sweep axis '{key}' repeats the value '{text}': duplicate axis values \
                     multiply the space with identical design points"
                ),
                "drop the duplicate; every entry of a sweep axis scales the campaign cost".into(),
            ));
        }
    }

    if campaign.sets("model_axes") && campaign.model_axes.is_trivial() {
        let span = or_top(model_axes_block(ctx.file).map(|b| b.keyword));
        out.push(Draft::new(
            span,
            "model_axes declares only the identity variant (width [1] x depth [1]): the block \
             is a no-op"
                .into(),
            "add more width/depth multipliers, or delete the block".into(),
        ));
    }
    out
}

/// Q002: a sample/keep budget at least as large as the (sharded) space
/// silently degrades the strategy to an exhaustive walk.
fn budget_covers_space(ctx: &LintContext<'_>) -> Vec<Draft> {
    let positions = shard_positions(ctx.campaign);
    if positions == 0 {
        return Vec::new(); // Q012 reports the empty selection.
    }
    let (_, num_shards) = ctx.campaign.shard;
    let scope = if num_shards > 1 {
        format!("this shard's {positions}-point share of the space")
    } else {
        format!("the {positions}-point space")
    };
    let span = or_top(strategy_span(ctx.file));
    match ctx.campaign.strategy {
        StrategyChoice::Random { n, .. } if n >= positions => vec![Draft::new(
            span,
            format!(
                "random({n}) requests at least as many samples as {scope} holds: the \
                 selection degrades to an exhaustive walk"
            ),
            "lower the sample count, or drop the strategy (exhaustive is the default)".into(),
        )],
        StrategyChoice::Halving { keep, .. } if keep >= positions => vec![Draft::new(
            span,
            format!(
                "halving keeps {keep} survivors but {scope} has no more candidates: every \
                 point survives and the strategy degrades to an exhaustive walk"
            ),
            "lower the keep count, or drop the strategy (exhaustive is the default)".into(),
        )],
        _ => Vec::new(),
    }
}

/// Q003: successive halving shrinks the pool by at most half per round
/// (never below `keep`), so over-provisioned `rounds` converge early —
/// the trailing rounds never execute, and because the fidelity ladder
/// is keyed to the *declared* round count, the last round that does
/// run ranks survivors on a truncated layer prefix instead of the full
/// model.
fn halving_rounds_excess(ctx: &LintContext<'_>) -> Vec<Draft> {
    let StrategyChoice::Halving { keep, rounds } = ctx.campaign.strategy else {
        return Vec::new();
    };
    let positions = shard_positions(ctx.campaign);
    if keep >= positions {
        return Vec::new(); // Q002 reports the degenerate budget.
    }
    // Rounds actually needed to shrink `positions` down to `keep`.
    let mut survivors = positions;
    let mut needed = 0usize;
    while survivors > keep {
        survivors = (survivors / 2).max(keep);
        needed += 1;
    }
    if rounds <= needed {
        return Vec::new();
    }
    let skipped = rounds - needed;
    let fidelity = 1u64 << skipped.min(63);
    vec![Draft::new(
        or_top(strategy_span(ctx.file)),
        format!(
            "halving({keep}, rounds = {rounds}) converges to {keep} survivor(s) after \
             {needed} round(s) over {positions} points: {skipped} round(s) never run, and \
             the final ranking scores only 1/{fidelity} of each model's layers"
        ),
        format!("use rounds = {needed} so the last executed round ranks at full fidelity"),
    )]
}

/// Q004: the row-stationary mapper keeps one kernel row of weights and
/// ifmap per PE; a scratchpad smaller than the kernel clamps residency
/// to a single element and the resulting tiling is meaningless.
fn spad_insufficient(ctx: &LintContext<'_>) -> Vec<Draft> {
    let mut out = Vec::new();
    let models = ctx.campaign.models();
    if models.is_empty() {
        return out;
    }
    let sweep = sweep_block(ctx.file);
    for (index, spad) in ctx.campaign.sweep.spads.iter().enumerate() {
        // A model is affected when any compute layer's kernel row
        // exceeds the per-PE ifmap or filter residency.
        let affected: Vec<(&Model, &Layer)> = models
            .iter()
            .filter_map(|model| {
                model
                    .layers
                    .iter()
                    .filter(|l| l.kind != LayerKind::Pool)
                    .filter(|l| spad.filter_entries < l.kernel || spad.ifmap_entries < l.kernel)
                    .max_by_key(|l| l.kernel)
                    .map(|layer| (model, layer))
            })
            .collect();
        let Some((worst_model, worst_layer)) =
            affected.iter().max_by_key(|(_, l)| l.kernel).copied()
        else {
            continue;
        };
        let every = affected.len() == models.len();
        let scope = if every {
            "every workload model is affected".to_string()
        } else {
            format!("{} of {} workload models affected", affected.len(), models.len())
        };
        let span = or_top(list_item_span(sweep, "spad", index).or(entry_span(sweep, "spad")));
        out.push(Draft::leveled(
            span,
            format!(
                "spad({}, {}, {}) cannot hold one {}x{} kernel row: layer '{}' of {} needs \
                 at least {} ifmap and filter entries per PE ({scope})",
                spad.ifmap_entries,
                spad.filter_entries,
                spad.psum_entries,
                worst_layer.kernel,
                worst_layer.kernel,
                worst_layer.name,
                worst_model.name,
                worst_layer.kernel,
            ),
            "the mapper clamps residency to one element and the tiling is meaningless; grow \
             the ifmap/filter entries to at least the largest swept kernel"
                .into(),
            // Degenerate for the whole workload: promote to deny.
            if every { Level::Deny } else { Level::Warn },
        ));
    }
    out
}

/// Q005: when even the *smallest* compute layer's ifmap (at the
/// narrowest swept activation width) exceeds the GLB, every layer of
/// that model refetches its ifmap from DRAM once per filter tile — the
/// buffer is uselessly small for the workload.
fn glb_below_working_set(ctx: &LintContext<'_>) -> Vec<Draft> {
    let mut out = Vec::new();
    let Some(min_act_bits) =
        ctx.campaign.sweep.pe_types.iter().map(|pe| pe.act_bits()).min()
    else {
        return out;
    };
    let sweep = sweep_block(ctx.file);
    for (index, glb_kib) in ctx.campaign.sweep.glb_kib.iter().enumerate() {
        let glb_bytes = (glb_kib * 1024) as u64;
        for model in ctx.campaign.models() {
            let Some(smallest) = model
                .layers
                .iter()
                .filter(|l| l.kind != LayerKind::Pool)
                .min_by_key(|l| l.ifmap_elems())
            else {
                continue;
            };
            let bytes = smallest.ifmap_elems() * min_act_bits as u64 / 8;
            if bytes <= glb_bytes {
                continue;
            }
            let span =
                or_top(list_item_span(sweep, "glb_kib", index).or(entry_span(sweep, "glb_kib")));
            out.push(Draft::new(
                span,
                format!(
                    "glb_kib = {glb_kib}: even {}'s smallest layer ('{}', {bytes} B ifmap at \
                     {min_act_bits}-bit activations) exceeds the {glb_bytes} B global buffer, \
                     so every layer refetches its ifmap from DRAM once per filter tile",
                    model.name, smallest.name,
                ),
                "grow glb_kib past the smallest per-layer ifmap, or expect DRAM-bound results"
                    .into(),
            ));
        }
    }
    out
}

/// Q006: an `accuracy { ... }` entry for a precision outside the
/// sweep's `pe_type` axis is never consulted by any figure or front.
fn accuracy_unswept_precision(ctx: &LintContext<'_>) -> Vec<Draft> {
    let mut out = Vec::new();
    for (model, entries) in &ctx.campaign.accuracy {
        for &(pe, _) in entries {
            if ctx.campaign.sweep.pe_types.contains(&pe) {
                continue;
            }
            let key = pe_key(pe);
            let span = model_block(ctx.file, model).and_then(|block| {
                block.stmts.iter().find_map(|stmt| match stmt {
                    ModelStmt::Accuracy(acc) => {
                        acc.entries.iter().find(|kv| kv.key.node == key).map(|kv| kv.key.span)
                    }
                    _ => None,
                })
            });
            out.push(Draft::new(
                or_top(span),
                format!(
                    "accuracy for '{key}' in model '{model}' is never consulted: the sweep's \
                     pe_type axis does not include {key}"
                ),
                format!("add {key} to sweep.pe_type, or drop the entry"),
            ));
        }
    }
    out
}

/// Q007: overriding the same layer twice in a `like` model is legal
/// (later fields win per overlapping key) but almost always a spec
/// editing accident.
fn shadowed_override(ctx: &LintContext<'_>) -> Vec<Draft> {
    let mut out = Vec::new();
    for section in &ctx.file.sections {
        let Section::Model(block) = section else { continue };
        if block.like.is_none() {
            continue;
        }
        let layers = layer_stmts(block);
        for (index, stmt) in layers.iter().enumerate() {
            if layers[index + 1..].iter().any(|later| later.name.node == stmt.name.node) {
                out.push(Draft::new(
                    stmt.name.span,
                    format!(
                        "layer '{}' of model '{}' is overridden again further down: \
                         overlapping fields silently take the later value",
                        stmt.name.node, block.name.node,
                    ),
                    format!(
                        "merge the overrides into one 'layer {} {{ ... }}' statement",
                        stmt.name.node
                    ),
                ));
            }
        }
    }
    out
}

/// Q008: consecutive layers of a custom (non-`like`) stack must agree
/// on geometry — a conv/pool expects the previous layer's output map,
/// an fc expects its flattened element count. Zoo and `like` models are
/// exempt: residual architectures legitimately branch.
fn layer_chain_mismatch(ctx: &LintContext<'_>) -> Vec<Draft> {
    let mut out = Vec::new();
    for workload in &ctx.campaign.workload {
        let WorkloadModel::Custom(model) = workload else { continue };
        let Some(block) = model_block(ctx.file, &model.name) else { continue };
        if block.like.is_some() {
            continue;
        }
        let stmts = layer_stmts(block);
        let aligned = stmts.len() == model.layers.len();
        for index in 1..model.layers.len() {
            let prev = &model.layers[index - 1];
            let cur = &model.layers[index];
            let span = if aligned { stmts[index].span } else { block.name.span };
            if cur.kind == LayerKind::FullyConnected {
                let produced = if prev.kind == LayerKind::FullyConnected {
                    prev.out_c
                } else {
                    prev.out_hw() * prev.out_hw() * prev.out_c
                };
                if cur.in_c != produced {
                    out.push(Draft::new(
                        span,
                        format!(
                            "fc '{}' expects {} inputs but '{}' produces {} ({}x{}x{} \
                             flattened)",
                            cur.name,
                            cur.in_c,
                            prev.name,
                            produced,
                            prev.out_hw(),
                            prev.out_hw(),
                            prev.out_c,
                        ),
                        format!("set in = {produced} on '{}'", cur.name),
                    ));
                }
            } else if cur.in_hw != prev.out_hw() || cur.in_c != prev.out_c {
                out.push(Draft::new(
                    span,
                    format!(
                        "layer '{}' expects a {}x{}x{} input but '{}' produces {}x{}x{}",
                        cur.name,
                        cur.in_hw,
                        cur.in_hw,
                        cur.in_c,
                        prev.name,
                        prev.out_hw(),
                        prev.out_hw(),
                        prev.out_c,
                    ),
                    format!(
                        "set in = {} and channels = {} on '{}'",
                        prev.out_hw(),
                        prev.out_c,
                        cur.name
                    ),
                ));
            }
        }
    }
    out
}

/// Q009: width multipliers round to integer channel counts and depth
/// multipliers only repeat stride-1 shape-preserving convs, so distinct
/// `model_axes` variants can lower to byte-identical layer stacks —
/// every such pair re-evaluates the same model under a different cache
/// identity.
fn collapsed_variants(ctx: &LintContext<'_>) -> Vec<Draft> {
    let axes = &ctx.campaign.model_axes;
    if axes.len() < 2 {
        return Vec::new();
    }
    let models = ctx.campaign.models();
    let variants: Vec<ModelVariant> = (0..axes.len()).filter_map(|v| axes.variant(v)).collect();
    let lowered: Vec<Vec<Model>> = variants
        .iter()
        .map(|v| models.iter().map(|m| scale_model(m, v.width, v.depth)).collect())
        .collect();
    let span = or_top(model_axes_block(ctx.file).map(|b| b.keyword));
    let label = |v: &ModelVariant| format!("w{}d{}", v.width, v.depth);
    let mut out = Vec::new();
    for i in 0..variants.len() {
        for j in i + 1..variants.len() {
            let collapsed: Vec<&str> = models
                .iter()
                .enumerate()
                .filter(|&(k, _)| lowered[i][k].layers == lowered[j][k].layers)
                .map(|(_, m)| m.name.as_str())
                .collect();
            if collapsed.is_empty() {
                continue;
            }
            let hw = ctx.campaign.sweep.len();
            let message = if collapsed.len() == models.len() {
                format!(
                    "model_axes variants {} and {} lower every workload model to an \
                     identical layer stack: {hw} duplicate hardware evaluations per model",
                    label(&variants[i]),
                    label(&variants[j]),
                )
            } else {
                format!(
                    "model_axes variants {} and {} lower {} to identical layer stacks",
                    label(&variants[i]),
                    label(&variants[j]),
                    collapsed.join(", "),
                )
            };
            out.push(Draft::new(
                span,
                message,
                "scaled channel counts round to integers; spread the multipliers further \
                 apart (or drop one)"
                    .into(),
            ));
        }
    }
    out
}

/// Q010: persist plans that work but lose more than the author
/// probably intends.
fn persist_hazard(ctx: &LintContext<'_>) -> Vec<Draft> {
    let mut out = Vec::new();
    let block = persist_block(ctx.file);
    let persist = &ctx.campaign.persist;
    if persist.checkpoint.is_some() && !ctx.campaign.sets("every") {
        out.push(Draft::new(
            or_top(entry_span(block, "checkpoint")),
            "persist.checkpoint is set without an explicit 'every' flush interval: a crash \
             can lose up to 16 (the default) evaluated points per flush window"
                .into(),
            "pin 'every = N' so the durability/throughput trade-off is deliberate".into(),
        ));
    }
    if persist.frontier.is_some() && persist.db.is_none() {
        out.push(Draft::new(
            or_top(entry_span(block, "frontier")),
            "persist.frontier streams the Pareto surface but no 'db' is kept: dominated \
             points are discarded and the campaign cannot be re-summarized or merged later"
                .into(),
            "add 'db = \"...\"' alongside the frontier, or accept the loss".into(),
        ));
    }
    out
}

/// The checkpoint-journal manifest this campaign would write — the
/// exact counterpart of the Explorer's, computed without running
/// anything.
fn expected_manifest(campaign: &ResolvedCampaign) -> CampaignManifest {
    let positions = shard_positions(campaign);
    let total = match campaign.strategy {
        StrategyChoice::Exhaustive => positions,
        StrategyChoice::Random { n, .. } => n.min(positions),
        StrategyChoice::Halving { keep, .. } => keep.min(positions),
    };
    CampaignManifest {
        spec_fingerprint: DesignSpace::new(
            campaign.sweep.clone(),
            campaign.model_axes.clone(),
        )
        .fingerprint(),
        seed: campaign.seed,
        shard: campaign.shard.0,
        num_shards: campaign.shard.1,
        total,
        dataset: campaign.dataset.name().to_string(),
        models: campaign.models().into_iter().map(|m| m.name).collect(),
        strategy: campaign.strategy.descriptor(),
        model_axes: campaign.model_axes.clone(),
        campaign_fp: Some(campaign.fingerprint()),
    }
}

/// Q011: cross-examine the persist plan against what is already on
/// disk, reporting *all* incompatibilities as one diagnostic instead of
/// the first-mismatch `InvalidConfig` the runtime would throw.
fn resume_mismatch(ctx: &LintContext<'_>) -> Vec<Draft> {
    let mut out = Vec::new();
    let block = persist_block(ctx.file);
    let persist = &ctx.campaign.persist;

    if let Some(path) = &persist.checkpoint {
        let span = or_top(entry_span(block, "checkpoint"));
        if let Ok(text) = std::fs::read_to_string(path) {
            // A header line is only authoritative once newline-terminated;
            // the runtime renames torn journals aside and restarts them,
            // so a torn header is not a finding.
            if let Some((header, _)) = text.split_once('\n') {
                match Json::parse(header).map_err(|e| Error::ParseError(e.to_string()))
                    .and_then(|json| CampaignManifest::from_json(&json))
                {
                    Err(_) => out.push(Draft::new(
                        span,
                        format!(
                            "persist.checkpoint points at '{}', which is not a parsable \
                             qadam checkpoint journal: the run will fail to resume",
                            path.display()
                        ),
                        "delete the file, or point 'checkpoint' at a fresh path".into(),
                    )),
                    Ok(journal) => {
                        let ours = expected_manifest(ctx.campaign);
                        let mismatches = manifest_mismatches(&journal, &ours);
                        if !mismatches.is_empty() {
                            out.push(Draft::new(
                                span,
                                format!(
                                    "resuming '{}' will be rejected — the journal was \
                                     written for a different campaign: {}",
                                    path.display(),
                                    mismatches.join("; "),
                                ),
                                "start a fresh checkpoint path, or restore the spec the \
                                 journal was written for"
                                    .into(),
                            ));
                        }
                    }
                }
            }
        }
    }

    // The trace document versions independently of the campaign schema
    // lineage (DESIGN.md §11), so its envelope is checked exactly — the
    // ranged check would reject every healthy schema-1 trace.
    let exact_schema = Some(crate::obs::TRACE_SCHEMA);
    for (key, path, mut kind, exact, loaded) in [
        ("db", &persist.db, "qadam.evaldb", None, false),
        ("cache", &persist.cache, "qadam.pointcache", None, true),
        ("trace", &persist.trace, crate::obs::TRACE_KIND, exact_schema, false),
    ] {
        let Some(path) = path else { continue };
        let Ok(bytes) = std::fs::read(path) else { continue };
        // persist.db may be the columnar binary format (`qadam.qdb`);
        // its magic + schema envelope stands in for the JSON kind header.
        let is_kind = if key == "db" && crate::explore::qdb::is_qdb_bytes(&bytes) {
            kind = "qadam.qdb";
            crate::explore::qdb::check_qdb_envelope(&bytes).is_ok()
        } else {
            String::from_utf8(bytes)
                .ok()
                .and_then(|text| Json::parse(&text).ok())
                .map(|json| match exact {
                    Some(version) => {
                        crate::explore::persist::check_envelope_exact(&json, kind, version).is_ok()
                    }
                    None => crate::explore::persist::check_envelope(&json, kind).is_ok(),
                })
                .unwrap_or(false)
        };
        if is_kind {
            continue;
        }
        let consequence = if loaded {
            "the run will fail to load it"
        } else {
            "running the campaign would overwrite it"
        };
        out.push(Draft::new(
            or_top(entry_span(block, key)),
            format!(
                "persist.{key} points at existing '{}', which is not a {kind} document: \
                 {consequence}",
                path.display()
            ),
            format!("pick a different persist.{key} path, or remove the file"),
        ));
    }
    out
}

/// Every field on which resuming `journal` under `ours` would be
/// rejected, phrased `field (journal: X, spec: Y)`.
fn manifest_mismatches(journal: &CampaignManifest, ours: &CampaignManifest) -> Vec<String> {
    let mut out = Vec::new();
    let mut diff = |field: &str, j: String, c: String| {
        out.push(format!("{field} (journal: {j}, spec: {c})"));
    };
    if journal.model_axes != ours.model_axes {
        let render = |axes: &crate::arch::ModelAxes| {
            format!("width {:?} x depth {:?}", axes.width_mults, axes.depth_mults)
        };
        diff("model axes", render(&journal.model_axes), render(&ours.model_axes));
    }
    if journal.spec_fingerprint != ours.spec_fingerprint {
        diff(
            "sweep fingerprint",
            format!("{:016x}", journal.spec_fingerprint),
            format!("{:016x}", ours.spec_fingerprint),
        );
    }
    if journal.seed != ours.seed {
        diff("seed", journal.seed.to_string(), ours.seed.to_string());
    }
    if (journal.shard, journal.num_shards) != (ours.shard, ours.num_shards) {
        diff(
            "shard",
            format!("{}/{}", journal.shard, journal.num_shards),
            format!("{}/{}", ours.shard, ours.num_shards),
        );
    }
    if journal.total != ours.total {
        diff("design-point count", journal.total.to_string(), ours.total.to_string());
    }
    if journal.dataset != ours.dataset {
        diff("dataset", journal.dataset.clone(), ours.dataset.clone());
    }
    if journal.models != ours.models {
        diff("model set", journal.models.join(","), ours.models.join(","));
    }
    if journal.strategy != ours.strategy {
        diff("search strategy", journal.strategy.clone(), ours.strategy.clone());
    }
    if journal.campaign_fp != ours.campaign_fp {
        let render =
            |fp: Option<u64>| fp.map_or_else(|| "none".to_string(), |fp| format!("{fp:016x}"));
        diff("campaign fingerprint", render(journal.campaign_fp), render(ours.campaign_fp));
    }
    out
}

/// Q012: a round-robin shard index past the end of the joint space
/// walks zero design points — the campaign evaluates nothing.
fn empty_selection(ctx: &LintContext<'_>) -> Vec<Draft> {
    let len = ctx.campaign.sweep.len() * ctx.campaign.model_axes.len();
    let (shard, num_shards) = ctx.campaign.shard;
    if len == 0 || shard < len {
        return Vec::new();
    }
    vec![Draft::new(
        or_top(entry_span(campaign_block(ctx.file), "shard")),
        format!(
            "shard {shard}/{num_shards} of a {len}-point space selects no design points \
             (round-robin shards cover indices shard, shard + N, ...)"
        ),
        "use fewer shards, or grow the space past the shard index".into(),
    )]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_codes_are_unique_sorted_and_well_formed() {
        let codes: Vec<&str> = RULES.iter().map(|r| r.code).collect();
        let mut sorted = codes.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(codes, sorted, "codes must be unique and in ascending order");
        assert!(RULES.len() >= 10, "the registry guarantees at least ten rules");
        for rule in RULES {
            assert!(rule.code.len() == 4 && rule.code.starts_with('Q'), "{}", rule.code);
            assert!(rule.code[1..].chars().all(|c| c.is_ascii_digit()), "{}", rule.code);
            assert!(!rule.name.is_empty() && !rule.summary.is_empty());
        }
    }

    #[test]
    fn selectors_accept_codes_names_and_all() {
        let opts = LintOptions::parse("q004, persist-hazard", "all").unwrap();
        assert!(opts.denied("Q004") && opts.denied("Q010"));
        assert!(opts.allowed("Q001") && opts.allowed("Q012"));
        assert!(LintOptions::parse("Q999", "").is_err());
        assert!(LintOptions::parse("", "no-such-rule").is_err());
        let none = LintOptions::parse("", "").unwrap();
        assert!(!none.denied("Q001") && !none.allowed("Q001"));
    }

    #[test]
    fn allow_wins_over_deny() {
        let source = "sweep {\n  pe_type = [int16]\n  array = [8x8]\n}\nstrategy = random(50)\n";
        let opts = LintOptions::parse("all", "Q002").unwrap();
        let (_, _, findings) = lint_source(source, &opts);
        assert!(findings.is_empty(), "{findings:?}");
        let opts = LintOptions::parse("all", "").unwrap();
        let (_, _, findings) = lint_source(source, &opts);
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].level, Level::Deny, "--deny all escalates warnings");
    }

    #[test]
    fn findings_are_span_then_code_ordered() {
        // Two rules fire at different spans; order must follow spans.
        let source = "sweep {\n  pe_type = [int16, int16]\n  array = [8x8]\n}\n\
                      strategy = random(500)\n";
        let (_, _, findings) = lint_source(source, &LintOptions::default());
        assert_eq!(findings.len(), 2, "{findings:?}");
        let keys: Vec<(usize, usize, &str)> =
            findings.iter().map(|f| (f.span.start, f.span.end, f.code)).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted);
    }

    #[test]
    fn unresolvable_specs_produce_no_findings() {
        let (campaign, diags, findings) =
            lint_source("sweep {\n  pe_type = [int17]\n}\n", &LintOptions::default());
        assert!(campaign.is_none());
        assert!(diags.has_errors());
        assert!(findings.is_empty());
    }

    #[test]
    fn json_document_round_trips_and_counts_levels() {
        let source = "campaign {\n  shard = 3 / 4\n}\n\
                      sweep {\n  pe_type = [int16]\n  array = [8x8]\n  glb_kib = [64]\n  \
                      spad = [spad(12, 224, 24)]\n  dram_gbps = [8]\n  clock_ghz = [2]\n}\n";
        let (_, _, findings) = lint_source(source, &LintOptions::default());
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].code, "Q012");
        let json = to_json("t.qsl", source, &findings);
        let reparsed = Json::parse(&json.to_string_pretty()).unwrap();
        assert_eq!(reparsed, json, "pretty JSON must round-trip losslessly");
        let reparsed = Json::parse(&json.to_string_canonical()).unwrap();
        assert_eq!(reparsed, json, "canonical JSON must round-trip losslessly");
        assert_eq!(json.get("deny_count").and_then(Json::as_i64), Some(1));
        assert_eq!(json.get("warn_count").and_then(Json::as_i64), Some(0));
        let finding = &json.get("findings").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(finding.get("code").and_then(Json::as_str), Some("Q012"));
        assert_eq!(finding.get("line").and_then(Json::as_i64), Some(2));
    }

    #[test]
    fn resume_mismatch_recognizes_qdb_databases() {
        let dir = std::env::temp_dir().join(format!("qadam_lint_qdb_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let envelope = |schema: u32| {
            let mut bytes = crate::explore::QDB_MAGIC.to_vec();
            bytes.extend_from_slice(&schema.to_le_bytes());
            bytes
        };
        let good = dir.join("db.qdb");
        std::fs::write(&good, envelope(crate::explore::QDB_SCHEMA_VERSION)).unwrap();
        let bad = dir.join("bad.qdb");
        std::fs::write(&bad, envelope(99)).unwrap();
        let spec_for = |path: &std::path::Path| {
            format!(
                "sweep {{\n  pe_type = [int16]\n  array = [8x8]\n  glb_kib = [64]\n}}\n\
                 persist {{\n  db = \"{}\"\n}}\n",
                path.display()
            )
        };
        // A healthy qdb envelope passes the kind check (no JSON parse).
        let (_, _, findings) = lint_source(&spec_for(&good), &LintOptions::default());
        assert!(findings.iter().all(|f| f.code != "Q011"), "{findings:?}");
        // A qdb with an unsupported schema is flagged as such.
        let (_, _, findings) = lint_source(&spec_for(&bad), &LintOptions::default());
        assert!(
            findings.iter().any(|f| f.code == "Q011" && f.message.contains("qadam.qdb")),
            "{findings:?}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn expected_manifest_matches_an_executed_journal_header() {
        // The no-run resume check must agree byte-for-byte with what the
        // Explorer writes, or Q011 would reject every healthy resume.
        let dir = std::env::temp_dir().join(format!("qadam_lint_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let journal = dir.join("run.journal");
        let _ = std::fs::remove_file(&journal);
        let source = format!(
            "campaign {{\n  seed = 11\n}}\n\
             sweep {{\n  pe_type = [int16]\n  array = [4x4]\n  glb_kib = [64]\n}}\n\
             workload {{\n  models = [tiny]\n}}\n\
             model tiny {{\n  conv c {{ in = 8, channels = 3, out = 4, kernel = 3, stride = 1, pad = 1 }}\n}}\n\
             persist {{\n  checkpoint = \"{}\"\n  every = 1\n}}\n",
            journal.display()
        );
        let campaign = super::super::compile(&source, "t.qsl").unwrap();
        campaign.execute().unwrap();
        let text = std::fs::read_to_string(&journal).unwrap();
        let header = text.split_once('\n').unwrap().0;
        let written = CampaignManifest::from_json(&Json::parse(header).unwrap()).unwrap();
        let expected = expected_manifest(&campaign);
        assert!(manifest_mismatches(&written, &expected).is_empty());
        // And the full lint pass agrees: no Q011 on a healthy resume.
        let (_, _, findings) = lint_source(&source, &LintOptions::default());
        assert!(findings.iter().all(|f| f.code != "Q011"), "{findings:?}");
        let _ = std::fs::remove_file(&journal);
    }
}
