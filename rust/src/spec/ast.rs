//! Spanned abstract syntax tree for QSL.
//!
//! The parser produces this tree *before* any semantic interpretation:
//! keys are raw strings, values are loosely typed, and everything
//! carries its [`Span`] so the resolver can attach precise diagnostics.
//! Semantic meaning (which keys exist, which values they take) lives
//! entirely in [`super::resolve`].

use super::diag::Span;

/// A value with the span of the source text that produced it.
#[derive(Debug, Clone, PartialEq)]
pub struct Spanned<T> {
    /// The payload.
    pub node: T,
    /// Its source location.
    pub span: Span,
}

impl<T> Spanned<T> {
    /// Pair a payload with its location.
    pub fn new(node: T, span: Span) -> Self {
        Self { node, span }
    }
}

/// A parsed spec file: its sections, in source order.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SpecFile {
    /// Top-level sections in source order.
    pub sections: Vec<Section>,
}

/// One top-level section.
#[derive(Debug, Clone, PartialEq)]
pub enum Section {
    /// `campaign { ... }` — seed, workers, shard.
    Campaign(Block),
    /// `sweep { ... }` — hardware design-space axes.
    Sweep(Block),
    /// `model_axes { ... }` — model-hyperparameter axes (width/depth
    /// multipliers) swept jointly with the hardware.
    ModelAxes(Block),
    /// `strategy = ...` — the search strategy.
    Strategy(StrategyDecl),
    /// `workload { ... }` — dataset + model list.
    Workload(Block),
    /// `model NAME [like ZOO] { ... }` — a model definition.
    Model(ModelBlock),
    /// `persist { ... }` — db / cache / checkpoint / frontier paths.
    Persist(Block),
    /// `include "base.qsl"` — splice another spec file's sections in
    /// place of this statement. Resolved by the expansion pass
    /// ([`super::expand`]); the plain resolver rejects it.
    Include(IncludeDecl),
    /// `override SECTION { key = value ... }` — entry-wise merge into
    /// an (included) section. Resolved by the expansion pass.
    Override(OverrideBlock),
    /// `matrix { key = [v1, v2, ...] ... }` — expand this one spec into
    /// a campaign set (the cross product of every matrix axis). Resolved
    /// by the expansion pass.
    Matrix(Block),
}

/// `include "path.qsl"`.
#[derive(Debug, Clone, PartialEq)]
pub struct IncludeDecl {
    /// Span of the `include` keyword.
    pub keyword: Span,
    /// The quoted path, relative to the including file.
    pub path: Spanned<String>,
}

/// `override SECTION { ... }`.
#[derive(Debug, Clone, PartialEq)]
pub struct OverrideBlock {
    /// Span of the `override` keyword.
    pub keyword: Span,
    /// The targeted section name (`campaign`, `sweep`, `model_axes`,
    /// `workload`, `persist`).
    pub target: Spanned<String>,
    /// The entries to merge into the target section.
    pub block: Block,
}

/// A brace-delimited block of `key = value` statements.
#[derive(Debug, Clone, PartialEq)]
pub struct Block {
    /// Span of the introducing keyword (`campaign`, `sweep`, ...).
    pub keyword: Span,
    /// The block's statements, in source order.
    pub entries: Vec<KeyValue>,
}

/// One `key = value` statement.
#[derive(Debug, Clone, PartialEq)]
pub struct KeyValue {
    /// The key identifier.
    pub key: Spanned<String>,
    /// The assigned value.
    pub value: Value,
}

/// A value with its span.
#[derive(Debug, Clone, PartialEq)]
pub struct Value {
    /// What kind of value this is.
    pub kind: ValueKind,
    /// Source location of the whole value.
    pub span: Span,
}

/// The loosely-typed value grammar.
#[derive(Debug, Clone, PartialEq)]
pub enum ValueKind {
    /// Numeric literal.
    Num(f64),
    /// String literal.
    Str(String),
    /// Bare word (`cifar10`, `exhaustive`, `int16`, ...).
    Word(String),
    /// Array-dimension literal `RxC`.
    Dims(usize, usize),
    /// `A / B` fraction (shard designators).
    Fraction(f64, f64),
    /// Bracketed list.
    List(Vec<Value>),
    /// Call form `name(arg, key = arg, ...)` — `spad(...)`, `random(...)`.
    Call {
        /// The callee word.
        name: Spanned<String>,
        /// Positional and named arguments, in source order.
        args: Vec<Arg>,
    },
}

impl ValueKind {
    /// Human-readable kind label for "expected X, found Y" diagnostics.
    pub fn describe(&self) -> &'static str {
        match self {
            ValueKind::Num(_) => "a number",
            ValueKind::Str(_) => "a string",
            ValueKind::Word(_) => "a name",
            ValueKind::Dims(_, _) => "dimensions",
            ValueKind::Fraction(_, _) => "a fraction",
            ValueKind::List(_) => "a list",
            ValueKind::Call { .. } => "a call",
        }
    }
}

/// One call argument: positional (`64`) or named (`seed = 11`).
#[derive(Debug, Clone, PartialEq)]
pub struct Arg {
    /// The parameter name for named arguments, `None` for positional.
    pub name: Option<Spanned<String>>,
    /// The argument value.
    pub value: Value,
}

/// `strategy = <value>`.
#[derive(Debug, Clone, PartialEq)]
pub struct StrategyDecl {
    /// Span of the `strategy` keyword.
    pub keyword: Span,
    /// The strategy expression (word or call).
    pub value: Value,
}

/// `model NAME [like ZOO] { ... }`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelBlock {
    /// Span of the `model` keyword.
    pub keyword: Span,
    /// The model's name.
    pub name: Spanned<String>,
    /// Zoo model this definition derives from, when `like` is present.
    pub like: Option<Spanned<String>>,
    /// The block's statements.
    pub stmts: Vec<ModelStmt>,
}

/// A statement inside a `model` block.
#[derive(Debug, Clone, PartialEq)]
pub enum ModelStmt {
    /// `key = value` (currently only `dataset = ...`).
    KeyValue(KeyValue),
    /// `conv NAME { ... }`, `fc NAME { ... }`, `pool NAME { ... }`, or
    /// the override form `layer NAME { ... }` (only valid with `like`).
    Layer(LayerStmt),
    /// `accuracy { int16 = 91.2, ... }` — user-declared top-1
    /// accuracies per PE type (percent), feeding the Fig. 5/6-style
    /// accuracy fronts for custom and scaled models.
    Accuracy(AccuracyBlock),
}

/// An `accuracy { PE = PERCENT, ... }` block inside a model definition.
#[derive(Debug, Clone, PartialEq)]
pub struct AccuracyBlock {
    /// Span of the `accuracy` keyword.
    pub keyword: Span,
    /// `pe_type = percent` entries, in source order.
    pub entries: Vec<KeyValue>,
}

/// One layer statement.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerStmt {
    /// The layer keyword (`conv` / `fc` / `pool` / `layer`).
    pub kind: Spanned<String>,
    /// The layer's name.
    pub name: Spanned<String>,
    /// Comma-separated `field = number` entries.
    pub fields: Vec<KeyValue>,
    /// Span of the whole statement.
    pub span: Span,
}
