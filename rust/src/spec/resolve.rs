//! Semantic validation and lowering: AST → [`ResolvedCampaign`].
//!
//! The resolver walks a parsed [`SpecFile`], reports **every** semantic
//! problem into the shared [`Diagnostics`] batch (unknown keys with
//! "did you mean" suggestions, bad values, impossible layer shapes,
//! dangling model references, ...), and — when no errors remain —
//! lowers the spec into the concrete campaign types the rest of the
//! framework already speaks: [`SweepSpec`], [`dnn::Model`](Model),
//! [`StrategyChoice`], and a [`PersistPlan`].
//!
//! A [`ResolvedCampaign`] also owns the spec's *canonical form*
//! ([`ResolvedCampaign::canonical`]): a fully-explicit QSL rendering
//! that re-parses to the same campaign (a fixed point). The campaign
//! [`fingerprint`](ResolvedCampaign::fingerprint) is FNV-1a over the
//! canonical *identity* subset (everything that changes results:
//! sweep, seed, shard, strategy, dataset, model stacks — but not
//! worker counts or persistence paths), and is pinned into checkpoint
//! journals so resuming under an edited spec is rejected.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;

use super::ast::{
    AccuracyBlock, Arg, Block, KeyValue, LayerStmt, ModelBlock, ModelStmt, Section, SpecFile,
    StrategyDecl, Value, ValueKind,
};
use super::diag::{Diagnostics, Span};
use super::lexer::fmt_num;
use crate::arch::{ModelAxes, ScratchpadCfg, SweepSpec};
use crate::dnn::{model_for, Dataset, Layer, LayerKind, Model, ModelKind};
use crate::error::{Error, Result};
use crate::explore::Explorer;
use crate::pareto::{RandomSample, SuccessiveHalving};
use crate::quant::PeType;
use crate::util::text::{did_you_mean, name_list};

/// Canonical QSL keys of the zoo models ([`ModelKind::KEYS`]).
pub const ZOO_KEYS: [&str; 5] = ModelKind::KEYS;

/// Canonical QSL keys of the datasets ([`Dataset::KEYS`]).
pub const DATASET_KEYS: [&str; 3] = Dataset::KEYS;

/// Canonical QSL keys of the PE types.
pub const PE_KEYS: [&str; 4] = ["fp32", "int16", "lightpe1", "lightpe2"];

/// The canonical QSL key of a zoo model.
pub fn zoo_key(kind: ModelKind) -> &'static str {
    match kind {
        ModelKind::Vgg16 => "vgg16",
        ModelKind::ResNet20 => "resnet20",
        ModelKind::ResNet34 => "resnet34",
        ModelKind::ResNet50 => "resnet50",
        ModelKind::ResNet56 => "resnet56",
    }
}

/// The canonical QSL key of a dataset.
pub fn dataset_key(dataset: Dataset) -> &'static str {
    match dataset {
        Dataset::Cifar10 => "cifar10",
        Dataset::Cifar100 => "cifar100",
        Dataset::ImageNet => "imagenet",
    }
}

/// The canonical QSL key of a PE type.
pub fn pe_key(pe: PeType) -> &'static str {
    match pe {
        PeType::Fp32 => "fp32",
        PeType::Int16 => "int16",
        PeType::LightPe1 => "lightpe1",
        PeType::LightPe2 => "lightpe2",
    }
}

/// Datasets a zoo model is defined for (the CIFAR ResNets are 32×32
/// models; ResNet-34/50 assume the ImageNet stem).
fn valid_datasets(kind: ModelKind) -> &'static [Dataset] {
    match kind {
        ModelKind::Vgg16 => &[Dataset::Cifar10, Dataset::Cifar100, Dataset::ImageNet],
        ModelKind::ResNet20 | ModelKind::ResNet56 => &[Dataset::Cifar10, Dataset::Cifar100],
        ModelKind::ResNet34 | ModelKind::ResNet50 => &[Dataset::ImageNet],
    }
}

/// One workload entry: a zoo model (instantiated on the campaign
/// dataset at lowering time) or a fully-resolved custom model.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadModel {
    /// A paper zoo model, referenced by kind.
    Zoo(ModelKind),
    /// A user-defined model (custom stack, or a `like` derivation with
    /// its overrides already applied).
    Custom(Model),
}

/// The search strategy a campaign runs — the resolver's (and the CLI's)
/// concrete strategy selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyChoice {
    /// Walk every design point.
    Exhaustive,
    /// [`RandomSample`]`{ n, seed }`.
    Random {
        /// Number of points to sample.
        n: usize,
        /// Sampling seed.
        seed: u64,
    },
    /// [`SuccessiveHalving`]`{ keep, rounds }`.
    Halving {
        /// Survivors to fully evaluate.
        keep: usize,
        /// Halving rounds.
        rounds: usize,
    },
}

impl StrategyChoice {
    /// The [`Strategy::descriptor`](crate::pareto::Strategy::descriptor)
    /// this choice lowers to.
    pub fn descriptor(&self) -> String {
        match self {
            StrategyChoice::Exhaustive => "exhaustive".into(),
            StrategyChoice::Random { n, seed } => format!("random:{n}:{seed}"),
            StrategyChoice::Halving { keep, rounds } => format!("halving:{keep}:{rounds}"),
        }
    }

    /// Canonical QSL rendering (`random(64, seed = 11)`).
    pub fn canonical(&self) -> String {
        match self {
            StrategyChoice::Exhaustive => "exhaustive".into(),
            StrategyChoice::Random { n, seed } => format!("random({n}, seed = {seed})"),
            StrategyChoice::Halving { keep, rounds } => {
                format!("halving({keep}, rounds = {rounds})")
            }
        }
    }

    /// Parse the CLI's `--strategy` descriptor: `exhaustive`,
    /// `random:N[:SEED]` (SEED defaults to the campaign seed), or
    /// `halving:KEEP[:ROUNDS]` (ROUNDS defaults to 3).
    pub fn parse_cli(text: &str, campaign_seed: u64) -> Result<Self> {
        let bad = |detail: &str| {
            Error::ParseError(format!(
                "bad --strategy '{text}' ({detail}; expected exhaustive, random:N[:SEED], \
                 or halving:KEEP[:ROUNDS])"
            ))
        };
        let mut parts = text.split(':');
        let kind = parts.next().unwrap_or("");
        let arg1 = parts.next();
        let arg2 = parts.next();
        if parts.next().is_some() {
            return Err(bad("too many parameters"));
        }
        let parse_num = |value: Option<&str>, name: &str| -> Result<Option<u64>> {
            match value {
                None => Ok(None),
                Some(v) => v
                    .trim()
                    .parse::<u64>()
                    .map(Some)
                    .map_err(|_| bad(&format!("{name} is not an integer"))),
            }
        };
        match kind {
            "exhaustive" => {
                if arg1.is_some() {
                    return Err(bad("exhaustive takes no parameters"));
                }
                Ok(StrategyChoice::Exhaustive)
            }
            "random" => {
                let n = parse_num(arg1, "N")?.ok_or_else(|| bad("random needs N"))? as usize;
                let seed = parse_num(arg2, "SEED")?.unwrap_or(campaign_seed);
                Ok(StrategyChoice::Random { n, seed })
            }
            "halving" => {
                let keep =
                    parse_num(arg1, "KEEP")?.ok_or_else(|| bad("halving needs KEEP"))? as usize;
                let rounds = parse_num(arg2, "ROUNDS")?.unwrap_or(3) as usize;
                Ok(StrategyChoice::Halving { keep, rounds })
            }
            _ => Err(bad("unknown strategy")),
        }
    }

    /// Attach this choice to an explorer. `Exhaustive` attaches nothing:
    /// the explorer's default walk *is* exhaustive, and leaving it unset
    /// keeps `run()`'s eval-vector pre-sizing (the manifest descriptor is
    /// `"exhaustive"` either way, so journals are interchangeable).
    pub fn attach(&self, explorer: Explorer) -> Explorer {
        match *self {
            StrategyChoice::Exhaustive => explorer,
            StrategyChoice::Random { n, seed } => explorer.strategy(RandomSample { n, seed }),
            StrategyChoice::Halving { keep, rounds } => {
                explorer.strategy(SuccessiveHalving { keep, rounds })
            }
        }
    }
}

/// Where a campaign persists its artifacts (all optional).
#[derive(Debug, Clone, PartialEq)]
pub struct PersistPlan {
    /// Evaluation-database output path (`dse --save`).
    pub db: Option<PathBuf>,
    /// Content-addressed point-cache path (`dse --cache`).
    pub cache: Option<PathBuf>,
    /// Checkpoint-journal path (`dse --resume`).
    pub checkpoint: Option<PathBuf>,
    /// Journal flush interval in points (`dse --every`; default 16).
    pub every: usize,
    /// Streaming-frontier output path (`dse --frontier`).
    pub frontier: Option<PathBuf>,
    /// Deterministic event-trace output path (`dse --trace`); the
    /// wall-clock timing sidecar is written next to it
    /// ([`sidecar_path`](crate::obs::sidecar_path)).
    pub trace: Option<PathBuf>,
}

impl PersistPlan {
    /// An empty plan with the default flush interval.
    pub fn new() -> Self {
        Self { db: None, cache: None, checkpoint: None, every: 16, frontier: None, trace: None }
    }
}

impl Default for PersistPlan {
    fn default() -> Self {
        Self::new()
    }
}

/// A fully validated, fully lowered campaign — the meeting point of the
/// QSL front end and the flag-driven CLI (both construct one of these,
/// so `qadam run spec.qsl` and the equivalent `qadam dse` invocation
/// execute byte-identically).
#[derive(Debug, Clone)]
pub struct ResolvedCampaign {
    /// The hardware design space to sweep.
    pub sweep: SweepSpec,
    /// Model-hyperparameter axes swept jointly with the hardware
    /// (trivial — base models only — unless the spec declares a
    /// `model_axes` block or the CLI passes `--width-mults` /
    /// `--depth-mults`).
    pub model_axes: ModelAxes,
    /// The campaign dataset (labels the database; instantiates zoo
    /// workload models).
    pub dataset: Dataset,
    /// The workload, in evaluation order.
    pub workload: Vec<WorkloadModel>,
    /// User-declared top-1 accuracies (percent) per custom model, in
    /// workload order: `(model name, [(pe, top1), ...])`. Feeds the
    /// Fig. 5/6-style accuracy fronts for custom and scaled models;
    /// not part of the campaign identity (it changes no evaluation).
    pub accuracy: Vec<(String, Vec<(PeType, f64)>)>,
    /// Synthesis-noise seed.
    pub seed: u64,
    /// Worker threads (`0` = auto).
    pub workers: usize,
    /// Round-robin shard `(shard, num_shards)`.
    pub shard: (usize, usize),
    /// Search strategy.
    pub strategy: StrategyChoice,
    /// Persistence plan.
    pub persist: PersistPlan,
    /// Keys the spec set explicitly (vs. defaults) — the CLI consults
    /// this to reject contradictory flag overrides.
    set_keys: BTreeSet<String>,
}

impl ResolvedCampaign {
    /// Build a campaign directly (the flag-driven path). No keys count
    /// as "explicitly set", so flag merging never applies to these.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        sweep: SweepSpec,
        dataset: Dataset,
        workload: Vec<WorkloadModel>,
        seed: u64,
        workers: usize,
        shard: (usize, usize),
        strategy: StrategyChoice,
        persist: PersistPlan,
    ) -> Self {
        Self {
            sweep,
            model_axes: ModelAxes::default(),
            dataset,
            workload,
            accuracy: Vec::new(),
            seed,
            workers,
            shard,
            strategy,
            persist,
            set_keys: BTreeSet::new(),
        }
    }

    /// Whether the spec explicitly set `key` (`"seed"`, `"workers"`,
    /// `"shard"`, `"strategy.seed"`, `"db"`, `"cache"`, `"checkpoint"`,
    /// `"every"`, `"frontier"`, `"trace"`). Flag-built campaigns set
    /// nothing.
    pub fn sets(&self, key: &str) -> bool {
        self.set_keys.contains(key)
    }

    /// Record that `key` was explicitly set (used by the resolver and
    /// by CLI flag merging).
    pub fn mark_set(&mut self, key: &str) {
        self.set_keys.insert(key.to_string());
    }

    /// Materialize the workload as [`Model`]s, in evaluation order. Zoo
    /// entries instantiate on the campaign dataset, exactly like
    /// [`Explorer::dataset`] does, so spec-driven and flag-driven
    /// campaigns see identical models.
    pub fn models(&self) -> Vec<Model> {
        self.workload
            .iter()
            .map(|entry| match entry {
                WorkloadModel::Zoo(kind) => model_for(*kind, self.dataset),
                WorkloadModel::Custom(model) => model.clone(),
            })
            .collect()
    }

    /// The canonical QSL rendering of this campaign: fully explicit
    /// (every default spelled out), comment-free, deterministic.
    /// Re-parsing it resolves to the same campaign — `canonical` is a
    /// fixed point of `parse → resolve → canonical`.
    pub fn canonical(&self) -> String {
        self.render(false)
    }

    /// The canonical rendering of the campaign's *identity*: the fields
    /// that determine results. Worker counts and persistence paths are
    /// excluded — editing those must not invalidate a resume.
    pub fn canonical_identity(&self) -> String {
        self.render(true)
    }

    /// FNV-1a fingerprint of [`Self::canonical_identity`]. Pinned into
    /// checkpoint-journal manifests via
    /// [`Explorer::campaign_fingerprint`], so a resume under an edited
    /// spec fails with a typed error instead of replaying foreign points.
    pub fn fingerprint(&self) -> u64 {
        crate::util::fnv1a_64(self.canonical_identity().as_bytes())
    }

    fn render(&self, identity_only: bool) -> String {
        let mut out = String::new();
        out.push_str("campaign {\n");
        out.push_str(&format!("  seed = {}\n", self.seed));
        if !identity_only {
            out.push_str(&format!("  workers = {}\n", self.workers));
        }
        out.push_str(&format!("  shard = {} / {}\n", self.shard.0, self.shard.1));
        out.push_str("}\n\n");
        out.push_str("sweep {\n");
        let words = |items: Vec<String>| items.join(", ");
        out.push_str(&format!(
            "  pe_type = [{}]\n",
            words(self.sweep.pe_types.iter().map(|&p| pe_key(p).to_string()).collect())
        ));
        out.push_str(&format!(
            "  array = [{}]\n",
            words(self.sweep.array_dims.iter().map(|&(r, c)| format!("{r}x{c}")).collect())
        ));
        out.push_str(&format!(
            "  glb_kib = [{}]\n",
            words(self.sweep.glb_kib.iter().map(|g| g.to_string()).collect())
        ));
        out.push_str(&format!(
            "  spad = [{}]\n",
            words(
                self.sweep
                    .spads
                    .iter()
                    .map(|s| format!(
                        "spad({}, {}, {})",
                        s.ifmap_entries, s.filter_entries, s.psum_entries
                    ))
                    .collect()
            )
        ));
        out.push_str(&format!(
            "  dram_gbps = [{}]\n",
            words(self.sweep.dram_bw_gbps.iter().map(|&b| fmt_num(b)).collect())
        ));
        out.push_str(&format!(
            "  clock_ghz = [{}]\n",
            words(self.sweep.clock_ghz.iter().map(|&c| fmt_num(c)).collect())
        ));
        out.push_str("}\n\n");
        // Joint model axes are identity (they change what is evaluated);
        // trivial axes are omitted so pre-joint specs render — and
        // fingerprint — exactly as they always have.
        if !self.model_axes.is_trivial() {
            out.push_str("model_axes {\n");
            out.push_str(&format!(
                "  width = [{}]\n",
                words(self.model_axes.width_mults.iter().map(|&w| fmt_num(w)).collect())
            ));
            out.push_str(&format!(
                "  depth = [{}]\n",
                words(self.model_axes.depth_mults.iter().map(|d| d.to_string()).collect())
            ));
            out.push_str("}\n\n");
        }
        out.push_str(&format!("strategy = {}\n\n", self.strategy.canonical()));
        out.push_str("workload {\n");
        out.push_str(&format!("  dataset = {}\n", dataset_key(self.dataset)));
        let names: Vec<String> = self
            .workload
            .iter()
            .map(|entry| match entry {
                WorkloadModel::Zoo(kind) => zoo_key(*kind).to_string(),
                WorkloadModel::Custom(model) => model.name.clone(),
            })
            .collect();
        out.push_str(&format!("  models = [{}]\n", names.join(", ")));
        out.push_str("}\n");
        for entry in &self.workload {
            if let WorkloadModel::Custom(model) = entry {
                // Declared accuracy is not identity (it changes no
                // evaluation), so resume survives accuracy edits.
                let accuracy = (!identity_only)
                    .then(|| {
                        self.accuracy
                            .iter()
                            .find(|(name, _)| *name == model.name)
                            .map(|(_, entries)| entries.as_slice())
                    })
                    .flatten();
                out.push('\n');
                out.push_str(&render_model(model, accuracy));
            }
        }
        if !identity_only {
            let mut lines: Vec<String> = Vec::new();
            if let Some(path) = &self.persist.db {
                lines.push(format!("  db = {}", quote(path)));
            }
            if let Some(path) = &self.persist.cache {
                lines.push(format!("  cache = {}", quote(path)));
            }
            if let Some(path) = &self.persist.checkpoint {
                lines.push(format!("  checkpoint = {}", quote(path)));
                lines.push(format!("  every = {}", self.persist.every));
            }
            if let Some(path) = &self.persist.frontier {
                lines.push(format!("  frontier = {}", quote(path)));
            }
            if let Some(path) = &self.persist.trace {
                lines.push(format!("  trace = {}", quote(path)));
            }
            if !lines.is_empty() {
                out.push_str("\npersist {\n");
                for line in lines {
                    out.push_str(&line);
                    out.push('\n');
                }
                out.push_str("}\n");
            }
        }
        out
    }

    /// One-screen resolved summary (the `qadam validate` output).
    pub fn summary(&self) -> String {
        let models = self.models();
        let points = self.sweep.len() * self.model_axes.len();
        let shard_points = if self.shard.1 > 1 {
            (points - self.shard.0.min(points)).div_ceil(self.shard.1)
        } else {
            points
        };
        let mut out = format!(
            "campaign: {} design points x {} models ({} evaluations{})\n",
            shard_points,
            models.len(),
            shard_points * models.len(),
            match self.strategy {
                StrategyChoice::Exhaustive => String::new(),
                _ => " before strategy selection".to_string(),
            }
        );
        out.push_str(&format!(
            "  sweep: {} pe_type x {} array x {} glb_kib x {} spad x {} dram_gbps x {} clock_ghz\n",
            self.sweep.pe_types.len(),
            self.sweep.array_dims.len(),
            self.sweep.glb_kib.len(),
            self.sweep.spads.len(),
            self.sweep.dram_bw_gbps.len(),
            self.sweep.clock_ghz.len(),
        ));
        if !self.model_axes.is_trivial() {
            out.push_str(&format!(
                "  model_axes: {} width x {} depth = {} variants per model\n",
                self.model_axes.width_mults.len(),
                self.model_axes.depth_mults.len(),
                self.model_axes.len(),
            ));
        }
        out.push_str(&format!("  dataset: {}\n", self.dataset.name()));
        let described: Vec<String> = self
            .workload
            .iter()
            .zip(&models)
            .map(|(entry, model)| match entry {
                WorkloadModel::Zoo(_) => format!("{} (zoo)", model.name),
                WorkloadModel::Custom(_) => format!(
                    "{} (custom, {} layers, {:.3e} MACs)",
                    model.name,
                    model.layers.len(),
                    model.total_macs() as f64
                ),
            })
            .collect();
        out.push_str(&format!("  models: {}\n", described.join(", ")));
        out.push_str(&format!("  strategy: {}\n", self.strategy.descriptor()));
        out.push_str(&format!(
            "  seed: {}, workers: {}, shard: {}/{}\n",
            self.seed,
            if self.workers == 0 { "auto".to_string() } else { self.workers.to_string() },
            self.shard.0,
            self.shard.1
        ));
        let mut persisted: Vec<String> = Vec::new();
        if let Some(p) = &self.persist.db {
            persisted.push(format!("db={}", p.display()));
        }
        if let Some(p) = &self.persist.cache {
            persisted.push(format!("cache={}", p.display()));
        }
        if let Some(p) = &self.persist.checkpoint {
            persisted.push(format!("checkpoint={} (every {})", p.display(), self.persist.every));
        }
        if let Some(p) = &self.persist.frontier {
            persisted.push(format!("frontier={}", p.display()));
        }
        if let Some(p) = &self.persist.trace {
            persisted.push(format!("trace={}", p.display()));
        }
        if !persisted.is_empty() {
            out.push_str(&format!("  persist: {}\n", persisted.join(" ")));
        }
        out.push_str(&format!("  fingerprint: {:016x}\n", self.fingerprint()));
        out
    }
}

fn quote(path: &std::path::Path) -> String {
    let text = path.display().to_string();
    let mut out = String::with_capacity(text.len() + 2);
    out.push('"');
    for ch in text.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn render_model(model: &Model, accuracy: Option<&[(PeType, f64)]>) -> String {
    let mut out = format!("model {} {{\n", model.name);
    out.push_str(&format!("  dataset = {}\n", dataset_key(model.dataset)));
    if let Some(entries) = accuracy.filter(|entries| !entries.is_empty()) {
        let rendered: Vec<String> = entries
            .iter()
            .map(|&(pe, top1)| format!("{} = {}", pe_key(pe), fmt_num(top1)))
            .collect();
        out.push_str(&format!("  accuracy {{ {} }}\n", rendered.join(", ")));
    }
    for layer in &model.layers {
        match layer.kind {
            LayerKind::Conv => out.push_str(&format!(
                "  conv {} {{ in = {}, channels = {}, out = {}, kernel = {}, stride = {}, \
                 pad = {} }}\n",
                layer.name, layer.in_hw, layer.in_c, layer.out_c, layer.kernel, layer.stride,
                layer.padding
            )),
            LayerKind::FullyConnected => out.push_str(&format!(
                "  fc {} {{ in = {}, out = {} }}\n",
                layer.name, layer.in_c, layer.out_c
            )),
            LayerKind::Pool => out.push_str(&format!(
                "  pool {} {{ in = {}, channels = {}, kernel = {}, stride = {} }}\n",
                layer.name, layer.in_hw, layer.in_c, layer.kernel, layer.stride
            )),
        }
    }
    out.push_str("}\n");
    out
}

// ---------------------------------------------------------------------------
// Resolution.

/// Resolve a parsed spec. Reports every semantic problem into `diags`;
/// returns `Some` only when no *errors* (warnings are fine) were
/// recorded by this pass or an earlier one.
pub fn resolve(file: &SpecFile, diags: &mut Diagnostics) -> Option<ResolvedCampaign> {
    let mut campaign_block: Option<&Block> = None;
    let mut sweep_block: Option<&Block> = None;
    let mut model_axes_block: Option<&Block> = None;
    let mut strategy_decl: Option<&StrategyDecl> = None;
    let mut workload_block: Option<&Block> = None;
    let mut persist_block: Option<&Block> = None;
    let mut model_blocks: Vec<&ModelBlock> = Vec::new();
    for section in &file.sections {
        let slot: (&mut Option<&Block>, &str, Span) = match section {
            Section::Campaign(b) => (&mut campaign_block, "campaign", b.keyword),
            Section::Sweep(b) => (&mut sweep_block, "sweep", b.keyword),
            Section::ModelAxes(b) => (&mut model_axes_block, "model_axes", b.keyword),
            Section::Workload(b) => (&mut workload_block, "workload", b.keyword),
            Section::Persist(b) => (&mut persist_block, "persist", b.keyword),
            Section::Strategy(decl) => {
                if strategy_decl.is_some() {
                    diags.error(decl.keyword, "duplicate 'strategy' declaration");
                } else {
                    strategy_decl = Some(decl);
                }
                continue;
            }
            Section::Model(model) => {
                model_blocks.push(model);
                continue;
            }
            Section::Include(inc) => {
                diags.error_help(
                    inc.keyword,
                    "'include' must be expanded before a spec can be resolved",
                    "run this spec through qadam run/validate/serve (or spec::expand), \
                     which splices includes in place",
                );
                continue;
            }
            Section::Override(ov) => {
                diags.error_help(
                    ov.keyword,
                    "'override' must be expanded before a spec can be resolved",
                    "run this spec through qadam run/validate/serve (or spec::expand), \
                     which merges override blocks into their target sections",
                );
                continue;
            }
            Section::Matrix(b) => {
                diags.error_help(
                    b.keyword,
                    "'matrix' must be expanded before a spec can be resolved",
                    "run this spec through qadam serve (or spec::expand), which expands \
                     the matrix cross product into a campaign set",
                );
                continue;
            }
        };
        let (stored, name, keyword) = slot;
        let block = match section {
            Section::Campaign(b) | Section::Sweep(b) | Section::ModelAxes(b)
            | Section::Workload(b) | Section::Persist(b) => b,
            _ => unreachable!(),
        };
        if stored.is_some() {
            diags.error(keyword, format!("duplicate '{name}' section"));
        } else {
            *stored = Some(block);
        }
    }

    let mut set_keys: BTreeSet<String> = BTreeSet::new();
    let (mut seed, mut workers, mut shard) = (7u64, 0usize, (0usize, 1usize));
    if let Some(block) = campaign_block {
        resolve_campaign_block(block, diags, &mut seed, &mut workers, &mut shard, &mut set_keys);
    }
    let sweep = match sweep_block {
        Some(block) => {
            set_keys.insert("sweep".into());
            resolve_sweep_block(block, diags)
        }
        None => SweepSpec::default(),
    };
    let model_axes = match model_axes_block {
        Some(block) => {
            set_keys.insert("model_axes".into());
            resolve_model_axes_block(block, diags)
        }
        None => ModelAxes::default(),
    };
    let raw_strategy = match strategy_decl {
        Some(decl) => {
            set_keys.insert("strategy".into());
            resolve_strategy(decl, diags)
        }
        None => RawStrategy::Exhaustive,
    };
    // Workload: dataset + model-name list (names resolved after the
    // model definitions are known).
    let mut dataset: Option<Dataset> = None;
    let mut model_names: Option<Vec<(String, Span)>> = None;
    if let Some(block) = workload_block {
        resolve_workload_block(block, diags, &mut dataset, &mut model_names, &mut set_keys);
    }
    let dataset = dataset.unwrap_or(Dataset::Cifar10);

    // Custom model definitions. `defined` tracks every definition by
    // name — including ones that failed to resolve — so the workload
    // pass below doesn't pile an "unknown model" error on top of the
    // definition's own diagnostics.
    let mut custom: Vec<(String, Model, Vec<(PeType, f64)>, Span)> = Vec::new();
    let mut defined: BTreeSet<String> = BTreeSet::new();
    for block in &model_blocks {
        let name = &block.name.node;
        defined.insert(name.clone());
        if ModelKind::parse(name).is_some() {
            diags.error_help(
                block.name.span,
                format!("model '{name}' shadows the built-in zoo model"),
                "pick a different name; zoo models are referenced directly in workload.models",
            );
            continue;
        }
        if custom.iter().any(|(n, _, _, _)| n == name) {
            diags.error(block.name.span, format!("duplicate model definition '{name}'"));
            continue;
        }
        if let Some((model, declared)) = resolve_model_block(block, dataset, diags) {
            custom.push((name.clone(), model, declared, block.name.span));
        }
    }

    // Workload model list → WorkloadModel entries.
    let mut workload: Vec<WorkloadModel> = Vec::new();
    let mut accuracy: Vec<(String, Vec<(PeType, f64)>)> = Vec::new();
    let mut used: BTreeSet<String> = BTreeSet::new();
    match &model_names {
        None => {
            workload = dataset.paper_models().into_iter().map(WorkloadModel::Zoo).collect();
        }
        Some(names) => {
            let mut seen: BTreeSet<String> = BTreeSet::new();
            for (name, span) in names {
                if !seen.insert(name.clone()) {
                    diags.error(*span, format!("duplicate model '{name}' in workload"));
                    continue;
                }
                if let Some((_, model, declared, _)) =
                    custom.iter().find(|(custom_name, _, _, _)| custom_name == name)
                {
                    used.insert(name.clone());
                    workload.push(WorkloadModel::Custom(model.clone()));
                    if !declared.is_empty() {
                        accuracy.push((model.name.clone(), declared.clone()));
                    }
                } else if defined.contains(name) {
                    // Defined but failed to resolve (or shadowed a zoo
                    // name): its definition already carries the errors.
                    used.insert(name.clone());
                } else if let Some(kind) = ModelKind::parse(name) {
                    if !valid_datasets(kind).contains(&dataset) {
                        diags.error_help(
                            *span,
                            format!(
                                "zoo model '{name}' is not defined for dataset '{}'",
                                dataset_key(dataset)
                            ),
                            format!(
                                "valid datasets for {name}: {}",
                                name_list(valid_datasets(kind).iter().map(|&d| dataset_key(d)))
                            ),
                        );
                    } else {
                        workload.push(WorkloadModel::Zoo(kind));
                    }
                } else {
                    let candidates: Vec<&str> = custom
                        .iter()
                        .map(|(n, _, _, _)| n.as_str())
                        .chain(ZOO_KEYS)
                        .collect();
                    let help = did_you_mean(name, candidates)
                        .map(|s| format!("did you mean '{s}'?"))
                        .unwrap_or_else(|| {
                            format!("known models: {}", name_list(ZOO_KEYS))
                        });
                    diags.error_help(*span, format!("unknown model '{name}'"), help);
                }
            }
        }
    }
    for (name, _, _, span) in &custom {
        if !used.contains(name) {
            diags.warn(*span, format!("model '{name}' is defined but not listed in workload.models"));
        }
    }

    let persist = match persist_block {
        Some(block) => resolve_persist_block(block, diags, &mut set_keys),
        None => PersistPlan::new(),
    };
    if persist.checkpoint.is_none() && set_keys.contains("every") {
        // Span information was consumed inside the block resolver; a
        // block-level warning is still precise enough.
        if let Some(block) = persist_block {
            diags.warn(block.keyword, "'every' has no effect without 'checkpoint'");
        }
    }

    // Finalize the strategy: an unseeded random() pins the campaign seed,
    // exactly like the CLI's random:N.
    let strategy = match raw_strategy {
        RawStrategy::Exhaustive => StrategyChoice::Exhaustive,
        RawStrategy::Random { n, seed: explicit } => {
            if explicit.is_some() {
                set_keys.insert("strategy.seed".into());
            }
            StrategyChoice::Random { n, seed: explicit.unwrap_or(seed) }
        }
        RawStrategy::Halving { keep, rounds } => StrategyChoice::Halving { keep, rounds },
    };

    if diags.has_errors() {
        return None;
    }
    Some(ResolvedCampaign {
        sweep,
        model_axes,
        dataset,
        workload,
        accuracy,
        seed,
        workers,
        shard,
        strategy,
        persist,
        set_keys,
    })
}

// -------------------------------------------------------------- value guards

fn expect_uint(diags: &mut Diagnostics, value: &Value, what: &str) -> Option<u64> {
    if let ValueKind::Num(x) = value.kind {
        if x >= 0.0 && x.fract() == 0.0 && x <= 9.0e15 {
            return Some(x as u64);
        }
        diags.error(
            value.span,
            format!("{what} must be a non-negative integer, found {}", fmt_num(x)),
        );
        return None;
    }
    diags.error(
        value.span,
        format!("{what} must be a non-negative integer, found {}", value.kind.describe()),
    );
    None
}

fn expect_pos_uint(diags: &mut Diagnostics, value: &Value, what: &str) -> Option<u64> {
    let x = expect_uint(diags, value, what)?;
    if x == 0 {
        diags.error(value.span, format!("{what} must be at least 1"));
        return None;
    }
    Some(x)
}

fn expect_pos_num(diags: &mut Diagnostics, value: &Value, what: &str) -> Option<f64> {
    if let ValueKind::Num(x) = value.kind {
        if x > 0.0 && x.is_finite() {
            return Some(x);
        }
        diags.error(value.span, format!("{what} must be a positive number, found {}", fmt_num(x)));
        return None;
    }
    diags.error(
        value.span,
        format!("{what} must be a positive number, found {}", value.kind.describe()),
    );
    None
}

fn expect_word<'v>(diags: &mut Diagnostics, value: &'v Value, what: &str) -> Option<&'v str> {
    match &value.kind {
        ValueKind::Word(word) => Some(word),
        other => {
            diags.error(value.span, format!("{what} must be a name, found {}", other.describe()));
            None
        }
    }
}

fn expect_string<'v>(diags: &mut Diagnostics, value: &'v Value, what: &str) -> Option<&'v str> {
    match &value.kind {
        ValueKind::Str(text) if !text.is_empty() => Some(text),
        ValueKind::Str(_) => {
            diags.error(value.span, format!("{what} must not be an empty string"));
            None
        }
        other => {
            diags.error(
                value.span,
                format!("{what} must be a quoted path string, found {}", other.describe()),
            );
            None
        }
    }
}

fn expect_list<'v>(diags: &mut Diagnostics, value: &'v Value, what: &str) -> Option<&'v [Value]> {
    match &value.kind {
        ValueKind::List(items) => {
            if items.is_empty() {
                diags.error(value.span, format!("{what} must list at least one value"));
                return None;
            }
            Some(items)
        }
        other => {
            diags.error(
                value.span,
                format!("{what} must be a [list], found {}", other.describe()),
            );
            None
        }
    }
}

/// Track duplicate keys within one block; returns true when `key` is new.
fn note_key(diags: &mut Diagnostics, seen: &mut BTreeSet<String>, kv: &KeyValue) -> bool {
    if seen.insert(kv.key.node.clone()) {
        true
    } else {
        diags.error(kv.key.span, format!("duplicate key '{}'", kv.key.node));
        false
    }
}

fn unknown_key(diags: &mut Diagnostics, kv: &KeyValue, section: &str, known: &[&str]) {
    let help = did_you_mean(&kv.key.node, known.iter().copied())
        .map(|s| format!("did you mean '{s}'?"))
        .unwrap_or_else(|| format!("{section} keys are: {}", name_list(known.iter().copied())));
    diags.error_help(
        kv.key.span,
        format!("unknown {section} key '{}'", kv.key.node),
        help,
    );
}

// ------------------------------------------------------------ section passes

fn resolve_campaign_block(
    block: &Block,
    diags: &mut Diagnostics,
    seed: &mut u64,
    workers: &mut usize,
    shard: &mut (usize, usize),
    set_keys: &mut BTreeSet<String>,
) {
    const KEYS: [&str; 3] = ["seed", "workers", "shard"];
    let mut seen = BTreeSet::new();
    for kv in &block.entries {
        if !note_key(diags, &mut seen, kv) {
            continue;
        }
        match kv.key.node.as_str() {
            "seed" => {
                if let Some(x) = expect_uint(diags, &kv.value, "seed") {
                    *seed = x;
                    set_keys.insert("seed".into());
                }
            }
            "workers" => {
                if let Some(x) = expect_uint(diags, &kv.value, "workers") {
                    *workers = x as usize;
                    set_keys.insert("workers".into());
                }
            }
            "shard" => match kv.value.kind {
                ValueKind::Fraction(i, n) => {
                    let ok = i >= 0.0 && n >= 1.0 && i.fract() == 0.0 && n.fract() == 0.0;
                    if !ok || i >= n {
                        diags.error(
                            kv.value.span,
                            format!(
                                "shard must be I / N with integers 0 <= I < N, found {} / {}",
                                fmt_num(i),
                                fmt_num(n)
                            ),
                        );
                    } else {
                        *shard = (i as usize, n as usize);
                        set_keys.insert("shard".into());
                    }
                }
                _ => diags.error_help(
                    kv.value.span,
                    format!("shard must be I / N, found {}", kv.value.kind.describe()),
                    "e.g. 'shard = 0 / 4' runs the first of four round-robin shards",
                ),
            },
            _ => unknown_key(diags, kv, "campaign", &KEYS),
        }
    }
}

fn resolve_sweep_block(block: &Block, diags: &mut Diagnostics) -> SweepSpec {
    const AXES: [&str; 6] = ["pe_type", "array", "glb_kib", "spad", "dram_gbps", "clock_ghz"];
    let mut sweep = SweepSpec::default();
    let mut seen = BTreeSet::new();
    for kv in &block.entries {
        if !note_key(diags, &mut seen, kv) {
            continue;
        }
        let Some(items) = (match kv.key.node.as_str() {
            key if AXES.contains(&key) => expect_list(diags, &kv.value, &format!("axis '{key}'")),
            _ => {
                unknown_key(diags, kv, "sweep", &AXES);
                continue;
            }
        }) else {
            continue;
        };
        match kv.key.node.as_str() {
            "pe_type" => {
                let mut pes = Vec::new();
                for item in items {
                    let Some(word) = expect_word(diags, item, "pe_type entry") else { continue };
                    match PeType::parse(word) {
                        Some(pe) => pes.push(pe),
                        None => {
                            let help = did_you_mean(word, PE_KEYS)
                                .map(|s| format!("did you mean '{s}'?"))
                                .unwrap_or_else(|| {
                                    format!("PE types are: {}", name_list(PE_KEYS))
                                });
                            diags.error_help(
                                item.span,
                                format!("unknown PE type '{word}'"),
                                help,
                            );
                        }
                    }
                }
                if !pes.is_empty() {
                    sweep.pe_types = pes;
                }
            }
            "array" => {
                let mut dims = Vec::new();
                for item in items {
                    match item.kind {
                        ValueKind::Dims(r, c) if (1..=256).contains(&r) && (1..=256).contains(&c) => {
                            dims.push((r, c));
                        }
                        ValueKind::Dims(r, c) => diags.error(
                            item.span,
                            format!("array dimensions {r}x{c} out of range (1..=256 per side)"),
                        ),
                        _ => diags.error_help(
                            item.span,
                            format!(
                                "array entries must be ROWSxCOLS dimensions, found {}",
                                item.kind.describe()
                            ),
                            "e.g. 'array = [8x8, 16x16]'",
                        ),
                    }
                }
                if !dims.is_empty() {
                    sweep.array_dims = dims;
                }
            }
            "glb_kib" => {
                let sizes: Vec<usize> = items
                    .iter()
                    .filter_map(|item| expect_pos_uint(diags, item, "glb_kib entry"))
                    .map(|x| x as usize)
                    .collect();
                if !sizes.is_empty() {
                    sweep.glb_kib = sizes;
                }
            }
            "spad" => {
                let mut spads = Vec::new();
                for item in items {
                    if let Some(cfg) = resolve_spad(item, diags) {
                        spads.push(cfg);
                    }
                }
                if !spads.is_empty() {
                    sweep.spads = spads;
                }
            }
            "dram_gbps" => {
                let bws: Vec<f64> = items
                    .iter()
                    .filter_map(|item| expect_pos_num(diags, item, "dram_gbps entry"))
                    .collect();
                if !bws.is_empty() {
                    sweep.dram_bw_gbps = bws;
                }
            }
            "clock_ghz" => {
                let clocks: Vec<f64> = items
                    .iter()
                    .filter_map(|item| expect_pos_num(diags, item, "clock_ghz entry"))
                    .collect();
                if !clocks.is_empty() {
                    sweep.clock_ghz = clocks;
                }
            }
            _ => unreachable!("axis keys are filtered above"),
        }
    }
    sweep
}

fn resolve_model_axes_block(block: &Block, diags: &mut Diagnostics) -> ModelAxes {
    const KEYS: [&str; 2] = ["width", "depth"];
    let mut axes = ModelAxes::default();
    let mut seen = BTreeSet::new();
    for kv in &block.entries {
        if !note_key(diags, &mut seen, kv) {
            continue;
        }
        match kv.key.node.as_str() {
            "width" => {
                let Some(items) = expect_list(diags, &kv.value, "axis 'width'") else { continue };
                let mut widths: Vec<f64> = Vec::new();
                for item in items {
                    let Some(w) = expect_pos_num(diags, item, "width multiplier") else {
                        continue;
                    };
                    if widths.contains(&w) {
                        diags.error(
                            item.span,
                            format!("duplicate width multiplier {}", fmt_num(w)),
                        );
                        continue;
                    }
                    widths.push(w);
                }
                if !widths.is_empty() {
                    axes.width_mults = widths;
                }
            }
            "depth" => {
                let Some(items) = expect_list(diags, &kv.value, "axis 'depth'") else { continue };
                let mut depths: Vec<usize> = Vec::new();
                for item in items {
                    let Some(d) = expect_pos_uint(diags, item, "depth multiplier") else {
                        continue;
                    };
                    let d = d as usize;
                    if depths.contains(&d) {
                        diags.error(item.span, format!("duplicate depth multiplier {d}"));
                        continue;
                    }
                    depths.push(d);
                }
                if !depths.is_empty() {
                    axes.depth_mults = depths;
                }
            }
            _ => unknown_key(diags, kv, "model_axes", &KEYS),
        }
    }
    axes
}

/// Resolve an `accuracy { PE = PERCENT, ... }` block: PE keys get
/// "did you mean" suggestions against [`PE_KEYS`]; values must be
/// percentages in (0, 100]. Entries return in [`PeType::ALL`] order so
/// the canonical rendering is deterministic.
fn resolve_accuracy_block(
    block: &AccuracyBlock,
    diags: &mut Diagnostics,
) -> Vec<(PeType, f64)> {
    let mut declared: Vec<(PeType, f64)> = Vec::new();
    let mut seen = BTreeSet::new();
    for kv in &block.entries {
        if !note_key(diags, &mut seen, kv) {
            continue;
        }
        let key = kv.key.node.as_str();
        let Some(pe) = PeType::parse(key) else {
            let help = did_you_mean(key, PE_KEYS)
                .map(|s| format!("did you mean '{s}'?"))
                .unwrap_or_else(|| format!("precisions are: {}", name_list(PE_KEYS)));
            diags.error_help(
                kv.key.span,
                format!("unknown precision '{key}' in accuracy block"),
                help,
            );
            continue;
        };
        let Some(top1) = expect_pos_num(diags, &kv.value, "accuracy") else { continue };
        if top1 > 100.0 {
            diags.error(
                kv.value.span,
                format!("accuracy must be a top-1 percentage (0, 100], found {}", fmt_num(top1)),
            );
            continue;
        }
        declared.push((pe, top1));
    }
    declared.sort_by_key(|(pe, _)| PeType::ALL.iter().position(|p| p == pe));
    declared
}

fn resolve_spad(value: &Value, diags: &mut Diagnostics) -> Option<ScratchpadCfg> {
    let bad = |diags: &mut Diagnostics, span: Span, detail: String| {
        diags.error_help(
            span,
            detail,
            "spad entries are spad(IFMAP_ENTRIES, FILTER_ENTRIES, PSUM_ENTRIES)",
        );
        None
    };
    match &value.kind {
        ValueKind::Call { name, args } if name.node == "spad" => {
            if args.len() != 3 || args.iter().any(|a| a.name.is_some()) {
                return bad(
                    diags,
                    value.span,
                    format!("spad(...) takes exactly 3 positional entries, found {}", args.len()),
                );
            }
            let mut entries = [0usize; 3];
            for (slot, arg) in entries.iter_mut().zip(args) {
                *slot = expect_pos_uint(diags, &arg.value, "spad entry")? as usize;
            }
            Some(ScratchpadCfg {
                ifmap_entries: entries[0],
                filter_entries: entries[1],
                psum_entries: entries[2],
            })
        }
        other => bad(
            diags,
            value.span,
            format!("spad entries must be spad(I, F, P) calls, found {}", other.describe()),
        ),
    }
}

enum RawStrategy {
    Exhaustive,
    Random { n: usize, seed: Option<u64> },
    Halving { keep: usize, rounds: usize },
}

fn resolve_strategy(decl: &StrategyDecl, diags: &mut Diagnostics) -> RawStrategy {
    const NAMES: [&str; 3] = ["exhaustive", "random", "halving"];
    let unknown = |diags: &mut Diagnostics, span: Span, word: &str| {
        let help = did_you_mean(word, NAMES)
            .map(|s| format!("did you mean '{s}'?"))
            .unwrap_or_else(|| format!("strategies are: {}", name_list(NAMES)));
        diags.error_help(span, format!("unknown strategy '{word}'"), help);
        RawStrategy::Exhaustive
    };
    match &decl.value.kind {
        ValueKind::Word(word) => match word.as_str() {
            "exhaustive" => RawStrategy::Exhaustive,
            "random" | "halving" => {
                diags.error_help(
                    decl.value.span,
                    format!("strategy '{word}' needs parameters"),
                    if word == "random" {
                        "e.g. 'strategy = random(64)' or 'random(64, seed = 11)'"
                    } else {
                        "e.g. 'strategy = halving(8)' or 'halving(8, rounds = 3)'"
                    },
                );
                RawStrategy::Exhaustive
            }
            other => unknown(diags, decl.value.span, other),
        },
        ValueKind::Call { name, args } => match name.node.as_str() {
            "exhaustive" => {
                diags.error(decl.value.span, "exhaustive takes no parameters");
                RawStrategy::Exhaustive
            }
            "random" => {
                let (n, named) = split_call_args(args, "random", &["seed"], diags);
                if n.is_none() {
                    diags.error_help(
                        decl.value.span,
                        "random(...) needs a sample count",
                        "e.g. 'strategy = random(64)' or 'random(64, seed = 11)'",
                    );
                }
                let n = n
                    .and_then(|v| expect_pos_uint(diags, v, "random sample count"))
                    .unwrap_or(1) as usize;
                let seed = named
                    .get("seed")
                    .and_then(|v| expect_uint(diags, v, "random seed"));
                RawStrategy::Random { n, seed }
            }
            "halving" => {
                let (keep, named) = split_call_args(args, "halving", &["rounds"], diags);
                if keep.is_none() {
                    diags.error_help(
                        decl.value.span,
                        "halving(...) needs a keep count",
                        "e.g. 'strategy = halving(8)' or 'halving(8, rounds = 3)'",
                    );
                }
                let keep = keep
                    .and_then(|v| expect_pos_uint(diags, v, "halving keep count"))
                    .unwrap_or(1) as usize;
                let rounds = named
                    .get("rounds")
                    .and_then(|v| expect_pos_uint(diags, v, "halving rounds"))
                    .unwrap_or(3) as usize;
                RawStrategy::Halving { keep, rounds }
            }
            other => unknown(diags, name.span, other),
        },
        other => {
            diags.error(
                decl.value.span,
                format!("strategy must be a name or a call, found {}", other.describe()),
            );
            RawStrategy::Exhaustive
        }
    }
}

/// Split call args into (the single positional, named-by-name). Extra
/// positionals and unknown names are reported.
fn split_call_args<'a>(
    args: &'a [Arg],
    call: &str,
    named_params: &[&str],
    diags: &mut Diagnostics,
) -> (Option<&'a Value>, BTreeMap<String, &'a Value>) {
    let mut positional: Option<&Value> = None;
    let mut named: BTreeMap<String, &Value> = BTreeMap::new();
    for arg in args {
        match &arg.name {
            None => {
                if positional.is_some() {
                    diags.error(
                        arg.value.span,
                        format!("{call}(...) takes one positional parameter"),
                    );
                } else {
                    positional = Some(&arg.value);
                }
            }
            Some(name) => {
                if !named_params.contains(&name.node.as_str()) {
                    let help = did_you_mean(&name.node, named_params.iter().copied())
                        .map(|s| format!("did you mean '{s}'?"))
                        .unwrap_or_else(|| {
                            format!(
                                "named parameters of {call}: {}",
                                name_list(named_params.iter().copied())
                            )
                        });
                    diags.error_help(
                        name.span,
                        format!("unknown parameter '{}' of {call}(...)", name.node),
                        help,
                    );
                } else if named.insert(name.node.clone(), &arg.value).is_some() {
                    diags.error(name.span, format!("duplicate parameter '{}'", name.node));
                }
            }
        }
    }
    (positional, named)
}

fn resolve_workload_block(
    block: &Block,
    diags: &mut Diagnostics,
    dataset: &mut Option<Dataset>,
    model_names: &mut Option<Vec<(String, Span)>>,
    set_keys: &mut BTreeSet<String>,
) {
    const KEYS: [&str; 2] = ["dataset", "models"];
    let mut seen = BTreeSet::new();
    for kv in &block.entries {
        if !note_key(diags, &mut seen, kv) {
            continue;
        }
        match kv.key.node.as_str() {
            "dataset" => {
                let Some(word) = expect_word(diags, &kv.value, "dataset") else { continue };
                match Dataset::parse(word) {
                    Some(d) => {
                        *dataset = Some(d);
                        set_keys.insert("dataset".into());
                    }
                    None => {
                        let help = did_you_mean(word, DATASET_KEYS)
                            .map(|s| format!("did you mean '{s}'?"))
                            .unwrap_or_else(|| {
                                format!("datasets are: {}", name_list(DATASET_KEYS))
                            });
                        diags.error_help(
                            kv.value.span,
                            format!("unknown dataset '{word}'"),
                            help,
                        );
                    }
                }
            }
            "models" => {
                let Some(items) = expect_list(diags, &kv.value, "models") else { continue };
                let mut names = Vec::new();
                for item in items {
                    if let Some(word) = expect_word(diags, item, "models entry") {
                        names.push((word.to_string(), item.span));
                    }
                }
                *model_names = Some(names);
                set_keys.insert("models".into());
            }
            _ => unknown_key(diags, kv, "workload", &KEYS),
        }
    }
}

fn resolve_persist_block(
    block: &Block,
    diags: &mut Diagnostics,
    set_keys: &mut BTreeSet<String>,
) -> PersistPlan {
    const KEYS: [&str; 6] = ["db", "cache", "checkpoint", "every", "frontier", "trace"];
    let mut plan = PersistPlan::new();
    let mut seen = BTreeSet::new();
    for kv in &block.entries {
        if !note_key(diags, &mut seen, kv) {
            continue;
        }
        match kv.key.node.as_str() {
            "db" | "cache" | "checkpoint" | "frontier" | "trace" => {
                let key = kv.key.node.as_str();
                if let Some(text) = expect_string(diags, &kv.value, &format!("persist.{key}")) {
                    let path = Some(PathBuf::from(text));
                    match key {
                        "db" => plan.db = path,
                        "cache" => plan.cache = path,
                        "checkpoint" => plan.checkpoint = path,
                        "trace" => plan.trace = path,
                        _ => plan.frontier = path,
                    }
                    set_keys.insert(key.to_string());
                }
            }
            "every" => {
                if let Some(x) = expect_pos_uint(diags, &kv.value, "every") {
                    plan.every = x as usize;
                    set_keys.insert("every".into());
                }
            }
            _ => unknown_key(diags, kv, "persist", &KEYS),
        }
    }
    plan
}

// -------------------------------------------------------------- model blocks

const CONV_FIELDS: [&str; 6] = ["in", "channels", "out", "kernel", "stride", "pad"];
const FC_FIELDS: [&str; 2] = ["in", "out"];
const POOL_FIELDS: [&str; 4] = ["in", "channels", "kernel", "stride"];

fn fields_for(kind: LayerKind) -> &'static [&'static str] {
    match kind {
        LayerKind::Conv => &CONV_FIELDS,
        LayerKind::FullyConnected => &FC_FIELDS,
        LayerKind::Pool => &POOL_FIELDS,
    }
}

/// Collect a layer statement's `field = N` entries against an allowed
/// field set, reporting unknown fields (with suggestions), duplicates,
/// and non-integer values. `pad` may be zero; everything else must be
/// positive.
fn collect_fields(
    stmt: &LayerStmt,
    allowed: &[&str],
    diags: &mut Diagnostics,
) -> BTreeMap<String, usize> {
    let mut out = BTreeMap::new();
    let mut seen = BTreeSet::new();
    for field in &stmt.fields {
        if !note_key(diags, &mut seen, field) {
            continue;
        }
        let key = field.key.node.as_str();
        if !allowed.contains(&key) {
            let help = did_you_mean(key, allowed.iter().copied())
                .map(|s| format!("did you mean '{s}'?"))
                .unwrap_or_else(|| {
                    format!(
                        "fields of a {} layer: {}",
                        stmt.kind.node,
                        name_list(allowed.iter().copied())
                    )
                });
            diags.error_help(
                field.key.span,
                format!("unknown field '{key}' for a {} layer", stmt.kind.node),
                help,
            );
            continue;
        }
        let value = if key == "pad" {
            expect_uint(diags, &field.value, "pad")
        } else {
            expect_pos_uint(diags, &field.value, key)
        };
        if let Some(x) = value {
            out.insert(key.to_string(), x as usize);
        }
    }
    out
}

/// Build a layer from a `conv`/`fc`/`pool` statement in a custom model.
fn build_layer(stmt: &LayerStmt, diags: &mut Diagnostics) -> Option<Layer> {
    let kind = match stmt.kind.node.as_str() {
        "conv" => LayerKind::Conv,
        "fc" => LayerKind::FullyConnected,
        "pool" => LayerKind::Pool,
        _ => unreachable!("parser only admits conv/fc/pool/layer"),
    };
    let fields = collect_fields(stmt, fields_for(kind), diags);
    let mut missing: Vec<&str> = Vec::new();
    let required: &[&str] = match kind {
        LayerKind::Conv => &["in", "channels", "out", "kernel"],
        LayerKind::FullyConnected => &["in", "out"],
        LayerKind::Pool => &["in", "channels", "kernel"],
    };
    for &field in required {
        if !fields.contains_key(field) {
            missing.push(field);
        }
    }
    if !missing.is_empty() {
        diags.error(
            stmt.span,
            format!(
                "{} layer '{}' is missing required field(s): {}",
                stmt.kind.node,
                stmt.name.node,
                name_list(missing.iter().copied())
            ),
        );
        return None;
    }
    let name = stmt.name.node.as_str();
    let layer = match kind {
        LayerKind::Conv => Layer::conv(
            name,
            fields["in"],
            fields["channels"],
            fields["out"],
            fields["kernel"],
            *fields.get("stride").unwrap_or(&1),
            *fields.get("pad").unwrap_or(&0),
        ),
        LayerKind::FullyConnected => Layer::fc(name, fields["in"], fields["out"]),
        LayerKind::Pool => {
            let kernel = fields["kernel"];
            Layer::pool(
                name,
                fields["in"],
                fields["channels"],
                kernel,
                *fields.get("stride").unwrap_or(&kernel),
            )
        }
    };
    check_geometry(&layer, stmt.span, diags).then_some(layer)
}

/// Reject shapes the mapper cannot evaluate (and that would underflow
/// `Layer::out_hw`).
fn check_geometry(layer: &Layer, span: Span, diags: &mut Diagnostics) -> bool {
    if layer.kernel > layer.in_hw + 2 * layer.padding {
        diags.error(
            span,
            format!(
                "layer '{}': kernel {} exceeds the padded input {} + 2*{}",
                layer.name, layer.kernel, layer.in_hw, layer.padding
            ),
        );
        return false;
    }
    true
}

/// Apply a `layer NAME { ... }` override onto a zoo-derived layer.
fn apply_override(layer: &mut Layer, stmt: &LayerStmt, diags: &mut Diagnostics) {
    let fields = collect_fields(stmt, fields_for(layer.kind), diags);
    for (key, value) in &fields {
        match (layer.kind, key.as_str()) {
            (LayerKind::FullyConnected, "in") => layer.in_c = *value,
            (LayerKind::FullyConnected, "out") => layer.out_c = *value,
            (_, "in") => layer.in_hw = *value,
            (_, "channels") => {
                layer.in_c = *value;
                if layer.kind == LayerKind::Pool {
                    layer.out_c = *value;
                }
            }
            (_, "out") => layer.out_c = *value,
            (_, "kernel") => layer.kernel = *value,
            (_, "stride") => layer.stride = *value,
            (_, "pad") => layer.padding = *value,
            _ => unreachable!("collect_fields filters to the kind's fields"),
        }
    }
    check_geometry(layer, stmt.span, diags);
}

fn resolve_model_block(
    block: &ModelBlock,
    default_dataset: Dataset,
    diags: &mut Diagnostics,
) -> Option<(Model, Vec<(PeType, f64)>)> {
    let before = diags.error_count();
    // Split the statements: `dataset = ...` vs accuracy vs layers.
    let mut dataset: Option<(Dataset, Span)> = None;
    let mut layers: Vec<&LayerStmt> = Vec::new();
    let mut declared: Option<Vec<(PeType, f64)>> = None;
    for stmt in &block.stmts {
        match stmt {
            ModelStmt::Accuracy(accuracy) => {
                if declared.is_some() {
                    diags.error(accuracy.keyword, "duplicate 'accuracy' block");
                    continue;
                }
                declared = Some(resolve_accuracy_block(accuracy, diags));
            }
            ModelStmt::KeyValue(kv) => match kv.key.node.as_str() {
                "dataset" => {
                    if dataset.is_some() {
                        diags.error(kv.key.span, "duplicate key 'dataset'");
                        continue;
                    }
                    let Some(word) = expect_word(diags, &kv.value, "dataset") else { continue };
                    match Dataset::parse(word) {
                        Some(d) => dataset = Some((d, kv.value.span)),
                        None => {
                            let help = did_you_mean(word, DATASET_KEYS)
                                .map(|s| format!("did you mean '{s}'?"))
                                .unwrap_or_else(|| {
                                    format!("datasets are: {}", name_list(DATASET_KEYS))
                                });
                            diags.error_help(
                                kv.value.span,
                                format!("unknown dataset '{word}'"),
                                help,
                            );
                        }
                    }
                }
                "accuracy" => {
                    diags.error_help(
                        kv.key.span,
                        "'accuracy' is a block, not a key",
                        "write 'accuracy { int16 = 91.2, lightpe1 = 90.1 }' with one entry \
                         per precision",
                    );
                }
                other => {
                    let help = did_you_mean(other, ["dataset", "accuracy"])
                        .map(|s| format!("did you mean '{s}'?"))
                        .unwrap_or_else(|| {
                            "model blocks take 'dataset = ...', an 'accuracy { ... }' block, \
                             and layer statements"
                                .into()
                        });
                    diags.error_help(
                        kv.key.span,
                        format!("unknown model key '{other}'"),
                        help,
                    );
                }
            },
            ModelStmt::Layer(layer) => layers.push(layer),
        }
    }
    let model_dataset = dataset.map(|(d, _)| d).unwrap_or(default_dataset);

    let model = match &block.like {
        Some(target) => {
            // A derivation of a zoo model: overrides only.
            let Some(kind) = ModelKind::parse(&target.node) else {
                let help = did_you_mean(&target.node, ZOO_KEYS)
                    .map(|s| format!("did you mean '{s}'?"))
                    .unwrap_or_else(|| format!("zoo models are: {}", name_list(ZOO_KEYS)));
                diags.error_help(
                    target.span,
                    format!("unknown zoo model '{}' after 'like'", target.node),
                    help,
                );
                return None;
            };
            if !valid_datasets(kind).contains(&model_dataset) {
                let span = dataset.map(|(_, s)| s).unwrap_or(target.span);
                diags.error_help(
                    span,
                    format!(
                        "zoo model '{}' is not defined for dataset '{}'",
                        target.node,
                        dataset_key(model_dataset)
                    ),
                    format!(
                        "valid datasets for {}: {}",
                        target.node,
                        name_list(valid_datasets(kind).iter().map(|&d| dataset_key(d)))
                    ),
                );
                return None;
            }
            let mut model = model_for(kind, model_dataset);
            model.name = block.name.node.clone();
            for stmt in layers {
                if stmt.kind.node != "layer" {
                    diags.error_help(
                        stmt.kind.span,
                        format!(
                            "'{}' statements are not allowed in a 'like' model",
                            stmt.kind.node
                        ),
                        "like-models only override existing layers with 'layer NAME { ... }'; \
                         define a model without 'like' to build a custom stack",
                    );
                    continue;
                }
                let layer_names: Vec<String> =
                    model.layers.iter().map(|l| l.name.clone()).collect();
                match model.layers.iter_mut().find(|l| l.name == stmt.name.node) {
                    Some(layer) => apply_override(layer, stmt, diags),
                    None => {
                        let help =
                            did_you_mean(&stmt.name.node, layer_names.iter().map(String::as_str))
                                .map(|s| format!("did you mean '{s}'?"))
                                .unwrap_or_else(|| {
                                    format!("{} has {} layers", target.node, layer_names.len())
                                });
                        diags.error_help(
                            stmt.name.span,
                            format!(
                                "model '{}' has no layer named '{}'",
                                block.name.node, stmt.name.node
                            ),
                            help,
                        );
                    }
                }
            }
            model
        }
        None => {
            // A custom stack: conv/fc/pool statements, in order.
            let mut built: Vec<Layer> = Vec::new();
            let mut names: BTreeSet<String> = BTreeSet::new();
            for stmt in layers {
                if stmt.kind.node == "layer" {
                    diags.error_help(
                        stmt.kind.span,
                        "'layer' overrides require 'like'",
                        "write 'model NAME like ZOO { layer ... }' to override a zoo layer, or \
                         use conv/fc/pool statements to define layers",
                    );
                    continue;
                }
                if !names.insert(stmt.name.node.clone()) {
                    diags.error(
                        stmt.name.span,
                        format!("duplicate layer name '{}'", stmt.name.node),
                    );
                    continue;
                }
                if let Some(layer) = build_layer(stmt, diags) {
                    built.push(layer);
                }
            }
            if built.is_empty() && diags.error_count() == before {
                diags.error(
                    block.name.span,
                    format!("model '{}' defines no layers", block.name.node),
                );
            }
            Model { name: block.name.node.clone(), dataset: model_dataset, layers: built }
        }
    };
    (diags.error_count() == before).then_some((model, declared.unwrap_or_default()))
}
