//! Spec expansion: `include` splicing plus `override`/`matrix`
//! composition — turning one QSL file into a *campaign set*.
//!
//! Three constructs layer on top of the base grammar:
//!
//! - `include "base.qsl"` splices another spec file's text in place of
//!   the statement, **before** parsing. The splice is textual (the
//!   included lines are bracketed by `# >>> include` / `# <<< include`
//!   comments), so every later diagnostic points into one combined
//!   source with coherent spans. Paths are relative to the including
//!   file; cycles and over-deep nesting are typed errors. Only lines
//!   whose first word is `include` are directives.
//! - `override SECTION { key = value ... }` merges entry-wise into the
//!   named section of the composed spec (replace same-key entries,
//!   append new ones, create the section when absent). This is how an
//!   including spec specializes a shared base without tripping the
//!   resolver's duplicate-section errors. A later `strategy = ...`
//!   declaration replaces an earlier one under expansion, since
//!   `strategy` is a single declaration with no block to override.
//! - `matrix { key = [v1, v2, ...] ... }` expands the spec into the
//!   cross product of its axes. Each matrix key routes to the section
//!   it belongs to (`seed`/`workers`/`shard` → campaign, sweep axes →
//!   sweep, `width`/`depth` → model_axes, `dataset`/`models` →
//!   workload, `strategy` → the strategy declaration); persist keys are
//!   rejected because `qadam serve` assigns per-fingerprint artifact
//!   directories itself.
//!
//! The plain [`resolve`](super::resolve) pass rejects all three
//! constructs with pointers here, so `spec::compile` stays a strict
//! single-campaign entry point while `qadam run`/`validate`/`serve` go
//! through [`expand_path`].

use std::path::{Path, PathBuf};

use super::ast::{
    Block, KeyValue, OverrideBlock, Section, SpecFile, Spanned, StrategyDecl, Value, ValueKind,
};
use super::diag::{Diagnostics, Span};
use super::lexer::fmt_num;
use super::parser::parse;
use super::resolve::{resolve, ResolvedCampaign};
use crate::error::{Error, Result};
use crate::util::text::{did_you_mean, name_list};

/// Maximum include nesting depth (a cycle guard for non-cyclic but
/// absurd include chains).
pub const MAX_INCLUDE_DEPTH: usize = 16;

/// Maximum number of campaigns one `matrix` block may expand to. A
/// batch bigger than this should be split across spec files, where each
/// file's campaigns stay reviewable.
pub const MAX_MATRIX_CAMPAIGNS: usize = 64;

/// Sections an `override` block may target (everything block-shaped;
/// `strategy` is a declaration — restating it wins under expansion).
pub const OVERRIDE_TARGETS: [&str; 5] = ["campaign", "sweep", "model_axes", "workload", "persist"];

/// One campaign produced by expansion.
#[derive(Debug, Clone)]
pub struct ExpandedCampaign {
    /// Human-readable matrix coordinates (`"glb_kib=64,seed=3"`; empty
    /// when the spec had no matrix block).
    pub label: String,
    /// The composed per-campaign AST (overrides and this combination's
    /// matrix entries applied) — what pre-flight lint runs against.
    pub file: SpecFile,
    /// The resolved campaign.
    pub campaign: ResolvedCampaign,
}

/// The result of expanding one spec file: the spliced source (for
/// rendering diagnostics), the campaign set, and every diagnostic the
/// pass collected. `campaigns` is empty whenever `diags` carries
/// errors.
#[derive(Debug)]
pub struct Expansion {
    /// Display name for diagnostics (the path as given).
    pub filename: String,
    /// The combined source after include splicing — the text all spans
    /// in `diags` refer to.
    pub source: String,
    /// The expanded campaign set, in deterministic matrix order.
    pub campaigns: Vec<ExpandedCampaign>,
    /// Errors and warnings from parsing, composition, and resolution.
    pub diags: Diagnostics,
}

impl Expansion {
    /// Whether expansion failed (in which case `campaigns` is empty).
    pub fn has_errors(&self) -> bool {
        self.diags.has_errors()
    }

    /// The campaign set, or a typed error carrying the full rendered
    /// diagnostic batch.
    pub fn into_result(self) -> Result<Vec<ExpandedCampaign>> {
        if self.diags.has_errors() {
            Err(self.diags.into_error(&self.source, &self.filename))
        } else {
            Ok(self.campaigns)
        }
    }
}

/// Splice every `include "path"` line of `path` (recursively) into one
/// combined source string. IO failures, cycles, and over-deep nesting
/// are typed errors; everything syntactic is left for the parser.
pub fn splice_includes(path: &Path) -> Result<String> {
    let mut stack: Vec<PathBuf> = Vec::new();
    splice_file(path, &mut stack)
}

/// Expand the spec file at `path` into its campaign set: splice
/// includes, parse, apply overrides, expand the matrix cross product,
/// and resolve each combination. The `qadam run`/`validate`/`serve`
/// entry point.
pub fn expand_path(path: &Path) -> Result<Expansion> {
    let source = splice_includes(path)?;
    Ok(expand_source(&source, &path.display().to_string()))
}

/// Expand already-loaded source. Includes cannot be resolved without a
/// file context, so any `include` statement is reported as an error
/// pointing at [`expand_path`].
pub fn expand_source(source: &str, filename: &str) -> Expansion {
    let mut diags = Diagnostics::new();
    let file = parse(source, &mut diags);

    // Partition: plain sections / override blocks / the matrix block.
    let mut plain: Vec<Section> = Vec::new();
    let mut overrides: Vec<OverrideBlock> = Vec::new();
    let mut matrix: Option<Block> = None;
    for section in file.sections {
        match section {
            Section::Include(inc) => {
                diags.error_help(
                    inc.keyword,
                    format!(
                        "cannot load include \"{}\" from in-memory source",
                        inc.path.node
                    ),
                    "includes resolve relative to the spec file's directory; expand via a \
                     file path (qadam run/validate/serve, or spec::expand_path)",
                );
            }
            Section::Override(ov) => overrides.push(ov),
            Section::Matrix(block) => {
                if matrix.is_some() {
                    diags.error_help(
                        block.keyword,
                        "duplicate 'matrix' section",
                        "merge the axes into one matrix block; the cross product already \
                         covers every axis combination",
                    );
                } else {
                    matrix = Some(block);
                }
            }
            other => plain.push(other),
        }
    }

    // Include layering: the *last* `strategy = ...` declaration wins
    // (an including spec restates the base's choice) instead of
    // tripping the resolver's duplicate-declaration error.
    if let Some(last) = plain.iter().rposition(|s| matches!(s, Section::Strategy(_))) {
        let mut index = 0usize;
        plain.retain(|s| {
            let keep = !matches!(s, Section::Strategy(_)) || index == last;
            index += 1;
            keep
        });
    }

    for ov in &overrides {
        apply_override(&mut plain, ov, &mut diags);
    }

    let (axes, matrix_span) = match &matrix {
        Some(block) => (matrix_axes(block, &mut diags), block.keyword),
        None => (Vec::new(), Span::at(0)),
    };

    // Cross product, in source order of the matrix axes.
    let mut combos: Vec<Vec<(usize, usize)>> = vec![Vec::new()];
    for (axis_index, axis) in axes.iter().enumerate() {
        let mut next = Vec::with_capacity(combos.len() * axis.values.len());
        for combo in &combos {
            for value_index in 0..axis.values.len() {
                let mut extended = combo.clone();
                extended.push((axis_index, value_index));
                next.push(extended);
            }
        }
        combos = next;
        if combos.len() > MAX_MATRIX_CAMPAIGNS {
            diags.error_help(
                matrix_span,
                format!("matrix expands to more than {MAX_MATRIX_CAMPAIGNS} campaigns"),
                "split the batch across several spec files and queue them all with \
                 qadam serve",
            );
            break;
        }
    }

    if diags.has_errors() {
        return Expansion {
            filename: filename.to_string(),
            source: source.to_string(),
            campaigns: Vec::new(),
            diags,
        };
    }

    let mut campaigns: Vec<ExpandedCampaign> = Vec::new();
    let mut fingerprints: Vec<(u64, String)> = Vec::new();
    for (combo_index, combo) in combos.iter().enumerate() {
        let mut sections = plain.clone();
        let mut label_parts: Vec<String> = Vec::new();
        for &(axis_index, value_index) in combo {
            let axis = &axes[axis_index];
            let value = axis.values[value_index].clone();
            label_parts.push(format!("{}={}", axis.key.node, render_value(&value)));
            match axis.route {
                Route::Strategy => {
                    let decl = StrategyDecl { keyword: axis.key.span, value };
                    let slot = sections.iter_mut().find_map(|s| match s {
                        Section::Strategy(d) => Some(d),
                        _ => None,
                    });
                    match slot {
                        Some(existing) => *existing = decl,
                        None => sections.push(Section::Strategy(decl)),
                    }
                }
                route => {
                    let block = find_or_create(&mut sections, route.target(), axis.key.span);
                    merge_entry(block, KeyValue { key: axis.key.clone(), value });
                }
            }
        }
        let file = SpecFile { sections };
        let mut combo_diags = Diagnostics::new();
        let resolved = resolve(&file, &mut combo_diags);
        match resolved {
            Some(campaign) if !combo_diags.has_errors() => {
                // Keep warnings once (every combination shares the same
                // composed base, so they would repeat verbatim).
                if combo_index == 0 {
                    diags.extend(combo_diags);
                }
                let label = label_parts.join(",");
                let fingerprint = campaign.fingerprint();
                if let Some((_, first)) =
                    fingerprints.iter().find(|(fp, _)| *fp == fingerprint)
                {
                    diags.warn_help(
                        matrix_span,
                        format!(
                            "matrix combinations '{first}' and '{label}' resolve to the \
                             same campaign fingerprint"
                        ),
                        "only identity fields (sweep axes, seed, shard, strategy, \
                         workload) distinguish campaigns; 'workers' and persist paths \
                         are transient",
                    );
                }
                fingerprints.push((fingerprint, label.clone()));
                campaigns.push(ExpandedCampaign { label, file, campaign });
            }
            _ => {
                diags.extend(combo_diags);
                return Expansion {
                    filename: filename.to_string(),
                    source: source.to_string(),
                    campaigns: Vec::new(),
                    diags,
                };
            }
        }
    }

    Expansion {
        filename: filename.to_string(),
        source: source.to_string(),
        campaigns,
        diags,
    }
}

fn splice_file(path: &Path, stack: &mut Vec<PathBuf>) -> Result<String> {
    let text = std::fs::read_to_string(path).map_err(|err| {
        Error::Io(std::io::Error::new(err.kind(), format!("{}: {err}", path.display())))
    })?;
    let canonical = std::fs::canonicalize(path).unwrap_or_else(|_| path.to_path_buf());
    if stack.contains(&canonical) {
        let chain: Vec<String> = stack.iter().map(|p| p.display().to_string()).collect();
        return Err(Error::InvalidConfig(format!(
            "include cycle: {} -> {}",
            chain.join(" -> "),
            path.display()
        )));
    }
    if stack.len() >= MAX_INCLUDE_DEPTH {
        return Err(Error::InvalidConfig(format!(
            "include nesting deeper than {MAX_INCLUDE_DEPTH} at {}",
            path.display()
        )));
    }
    stack.push(canonical);
    let dir = path.parent().map(Path::to_path_buf).unwrap_or_default();
    let mut out = String::with_capacity(text.len());
    for line in text.lines() {
        match include_target(line) {
            None => {
                out.push_str(line);
                out.push('\n');
            }
            Some(Err(message)) => {
                stack.pop();
                return Err(Error::ParseError(format!("{}: {message}", path.display())));
            }
            Some(Ok(rel)) => {
                let spliced = splice_file(&dir.join(rel), stack)?;
                out.push_str(&format!("# >>> include \"{rel}\"\n"));
                out.push_str(&spliced);
                out.push_str(&format!("# <<< include \"{rel}\"\n"));
            }
        }
    }
    stack.pop();
    Ok(out)
}

/// Recognize an `include "path"` directive line. Returns `None` for
/// ordinary lines, `Some(Err(why))` for a malformed directive.
fn include_target(line: &str) -> Option<std::result::Result<&str, String>> {
    let rest = line.trim_start().strip_prefix("include")?;
    if !rest.starts_with([' ', '\t', '"']) {
        return None; // a longer identifier, not the keyword
    }
    let rest = rest.trim_start();
    let Some(inner) = rest.strip_prefix('"') else {
        return Some(Err("expected a quoted path after 'include'".to_string()));
    };
    let Some(end) = inner.find('"') else {
        return Some(Err("unterminated include path".to_string()));
    };
    let tail = inner[end + 1..].trim_start();
    if !(tail.is_empty() || tail.starts_with('#')) {
        return Some(Err(format!("unexpected text after include path: '{tail}'")));
    }
    Some(Ok(&inner[..end]))
}

fn apply_override(plain: &mut Vec<Section>, ov: &OverrideBlock, diags: &mut Diagnostics) {
    let target = ov.target.node.as_str();
    if !OVERRIDE_TARGETS.contains(&target) {
        let help = if target == "strategy" {
            "restate 'strategy = ...' at top level instead; the last declaration wins \
             under expansion"
                .to_string()
        } else {
            did_you_mean(target, OVERRIDE_TARGETS)
                .map(|s| format!("did you mean '{s}'?"))
                .unwrap_or_else(|| {
                    format!("override targets are: {}", name_list(OVERRIDE_TARGETS))
                })
        };
        diags.error_help(ov.target.span, format!("cannot override '{target}'"), help);
        return;
    }
    let block = find_or_create(plain, target, ov.keyword);
    for entry in &ov.block.entries {
        merge_entry(block, entry.clone());
    }
}

fn matches_target(section: &Section, target: &str) -> bool {
    matches!(
        (section, target),
        (Section::Campaign(_), "campaign")
            | (Section::Sweep(_), "sweep")
            | (Section::ModelAxes(_), "model_axes")
            | (Section::Workload(_), "workload")
            | (Section::Persist(_), "persist")
    )
}

fn find_or_create<'a>(plain: &'a mut Vec<Section>, target: &str, keyword: Span) -> &'a mut Block {
    let position = match plain.iter().position(|s| matches_target(s, target)) {
        Some(position) => position,
        None => {
            let block = Block { keyword, entries: Vec::new() };
            plain.push(match target {
                "campaign" => Section::Campaign(block),
                "sweep" => Section::Sweep(block),
                "model_axes" => Section::ModelAxes(block),
                "workload" => Section::Workload(block),
                _ => Section::Persist(block),
            });
            plain.len() - 1
        }
    };
    match &mut plain[position] {
        Section::Campaign(b)
        | Section::Sweep(b)
        | Section::ModelAxes(b)
        | Section::Workload(b)
        | Section::Persist(b) => b,
        _ => unreachable!(),
    }
}

/// Replace the same-key entry in place, or append a new one.
fn merge_entry(block: &mut Block, entry: KeyValue) {
    match block.entries.iter_mut().find(|e| e.key.node == entry.key.node) {
        Some(existing) => *existing = entry,
        None => block.entries.push(entry),
    }
}

/// Where a matrix key's per-combination value lands.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Route {
    Campaign,
    Sweep,
    ModelAxes,
    Workload,
    Strategy,
}

impl Route {
    fn target(self) -> &'static str {
        match self {
            Route::Campaign => "campaign",
            Route::Sweep => "sweep",
            Route::ModelAxes => "model_axes",
            Route::Workload => "workload",
            Route::Strategy => "strategy",
        }
    }
}

const MATRIX_CAMPAIGN_KEYS: [&str; 3] = ["seed", "workers", "shard"];
const MATRIX_SWEEP_KEYS: [&str; 6] =
    ["pe_type", "array", "glb_kib", "spad", "dram_gbps", "clock_ghz"];
const MATRIX_MODEL_AXES_KEYS: [&str; 2] = ["width", "depth"];
const MATRIX_WORKLOAD_KEYS: [&str; 2] = ["dataset", "models"];
const MATRIX_PERSIST_KEYS: [&str; 5] = ["db", "cache", "checkpoint", "frontier", "every"];

struct MatrixAxis {
    key: Spanned<String>,
    route: Route,
    values: Vec<Value>,
}

fn matrix_axes(block: &Block, diags: &mut Diagnostics) -> Vec<MatrixAxis> {
    let mut axes: Vec<MatrixAxis> = Vec::new();
    for entry in &block.entries {
        let key = entry.key.node.as_str();
        if axes.iter().any(|a| a.key.node == key) {
            diags.error(entry.key.span, format!("duplicate matrix axis '{key}'"));
            continue;
        }
        let route = if key == "strategy" {
            Route::Strategy
        } else if MATRIX_CAMPAIGN_KEYS.contains(&key) {
            Route::Campaign
        } else if MATRIX_SWEEP_KEYS.contains(&key) {
            Route::Sweep
        } else if MATRIX_MODEL_AXES_KEYS.contains(&key) {
            Route::ModelAxes
        } else if MATRIX_WORKLOAD_KEYS.contains(&key) {
            Route::Workload
        } else if MATRIX_PERSIST_KEYS.contains(&key) {
            diags.error_help(
                entry.key.span,
                format!("cannot vary '{key}' in a matrix"),
                "persist paths are assigned per campaign fingerprint by qadam serve",
            );
            continue;
        } else {
            let candidates = MATRIX_CAMPAIGN_KEYS
                .iter()
                .chain(&MATRIX_SWEEP_KEYS)
                .chain(&MATRIX_MODEL_AXES_KEYS)
                .chain(&MATRIX_WORKLOAD_KEYS)
                .chain(std::iter::once(&"strategy"))
                .copied();
            let help = did_you_mean(key, candidates.clone())
                .map(|s| format!("did you mean '{s}'?"))
                .unwrap_or_else(|| format!("matrix keys are: {}", name_list(candidates)));
            diags.error_help(entry.key.span, format!("unknown matrix key '{key}'"), help);
            continue;
        };
        match &entry.value.kind {
            ValueKind::List(items) if !items.is_empty() => {
                // A matrix over sweep/model_axes/workload list keys sets
                // a *list-valued* key per combination, so each item must
                // itself be the value that key takes (possibly a list).
                axes.push(MatrixAxis {
                    key: entry.key.clone(),
                    route,
                    values: items.clone(),
                });
            }
            ValueKind::List(_) => {
                diags.error(entry.value.span, format!("matrix axis '{key}' is empty"));
            }
            other => {
                diags.error_help(
                    entry.value.span,
                    format!(
                        "matrix axis '{key}' must be a list of alternatives, found {}",
                        other.describe()
                    ),
                    format!("write {key} = [v1, v2, ...]"),
                );
            }
        }
    }
    axes
}

/// Render a value back to QSL-ish text (for matrix labels).
fn render_value(value: &Value) -> String {
    match &value.kind {
        ValueKind::Num(x) => fmt_num(*x),
        ValueKind::Str(s) => format!("\"{s}\""),
        ValueKind::Word(w) => w.clone(),
        ValueKind::Dims(rows, cols) => format!("{rows}x{cols}"),
        ValueKind::Fraction(num, den) => format!("{}/{}", fmt_num(*num), fmt_num(*den)),
        ValueKind::List(items) => {
            let inner: Vec<String> = items.iter().map(render_value).collect();
            format!("[{}]", inner.join(", "))
        }
        ValueKind::Call { name, args } => {
            let inner: Vec<String> = args
                .iter()
                .map(|arg| match &arg.name {
                    Some(n) => format!("{} = {}", n.node, render_value(&arg.value)),
                    None => render_value(&arg.value),
                })
                .collect();
            format!("{}({})", name.node, inner.join(", "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::StrategyChoice;

    fn write(dir: &Path, name: &str, text: &str) -> PathBuf {
        let path = dir.join(name);
        std::fs::write(&path, text).unwrap();
        path
    }

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("qadam_expand_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    const BASE: &str = "campaign { seed = 7 }\n\
                        sweep {\n  pe_type = [int16]\n  array = [8x8]\n  glb_kib = [64]\n}\n\
                        workload {\n  models = [resnet20]\n}\n";

    #[test]
    fn plain_spec_expands_to_one_campaign() {
        let expansion = expand_source(BASE, "base.qsl");
        assert!(!expansion.has_errors(), "{}", expansion.diags);
        assert_eq!(expansion.campaigns.len(), 1);
        assert_eq!(expansion.campaigns[0].label, "");
    }

    #[test]
    fn override_merges_into_target_section() {
        let source = format!("{BASE}override campaign {{ seed = 99 }}\n");
        let expansion = expand_source(&source, "t.qsl");
        assert!(!expansion.has_errors(), "{}", expansion.diags);
        let campaign = &expansion.campaigns[0].campaign;
        assert_eq!(campaign.seed, 99);
        // Overriding an absent section creates it.
        let source = format!("{BASE}override model_axes {{ width = [0.5, 1] }}\n");
        let expansion = expand_source(&source, "t.qsl");
        assert!(!expansion.has_errors(), "{}", expansion.diags);
        assert!(expansion.campaigns[0].campaign.canonical().contains("model_axes"));
    }

    #[test]
    fn override_unknown_target_is_an_error() {
        let source = format!("{BASE}override sweeep {{ glb_kib = [128] }}\n");
        let expansion = expand_source(&source, "t.qsl");
        assert!(expansion.has_errors());
        let rendered = expansion.diags.render(&expansion.source, "t.qsl");
        assert!(rendered.contains("did you mean 'sweep'?"), "{rendered}");
        assert!(expansion.campaigns.is_empty());
    }

    #[test]
    fn override_strategy_points_at_redeclaration() {
        let source = format!("{BASE}override strategy {{ n = 4 }}\n");
        let expansion = expand_source(&source, "t.qsl");
        assert!(expansion.has_errors());
        let rendered = expansion.diags.render(&expansion.source, "t.qsl");
        assert!(rendered.contains("last declaration wins"), "{rendered}");
    }

    #[test]
    fn last_strategy_declaration_wins() {
        let source = format!("strategy = exhaustive\n{BASE}strategy = random(2, seed = 5)\n");
        let expansion = expand_source(&source, "t.qsl");
        assert!(!expansion.has_errors(), "{}", expansion.diags);
        assert_eq!(
            expansion.campaigns[0].campaign.strategy,
            StrategyChoice::Random { n: 2, seed: 5 }
        );
    }

    #[test]
    fn matrix_expands_cross_product_in_order() {
        let source = format!("{BASE}matrix {{\n  seed = [1, 2]\n  glb_kib = [[64], [128]]\n}}\n");
        let expansion = expand_source(&source, "t.qsl");
        assert!(!expansion.has_errors(), "{}", expansion.diags);
        let labels: Vec<&str> =
            expansion.campaigns.iter().map(|c| c.label.as_str()).collect();
        assert_eq!(
            labels,
            [
                "seed=1,glb_kib=[64]",
                "seed=1,glb_kib=[128]",
                "seed=2,glb_kib=[64]",
                "seed=2,glb_kib=[128]"
            ]
        );
        // All four campaigns are distinct.
        let mut fingerprints: Vec<u64> =
            expansion.campaigns.iter().map(|c| c.campaign.fingerprint()).collect();
        fingerprints.sort_unstable();
        fingerprints.dedup();
        assert_eq!(fingerprints.len(), 4);
    }

    #[test]
    fn matrix_over_transients_warns_on_duplicate_fingerprints() {
        let source = format!("{BASE}matrix {{ workers = [1, 2] }}\n");
        let expansion = expand_source(&source, "t.qsl");
        assert!(!expansion.has_errors(), "{}", expansion.diags);
        assert_eq!(expansion.campaigns.len(), 2);
        let rendered = expansion.diags.render(&expansion.source, "t.qsl");
        assert!(rendered.contains("same campaign fingerprint"), "{rendered}");
    }

    #[test]
    fn matrix_rejects_unknown_and_persist_keys() {
        let source = format!("{BASE}matrix {{\n  sede = [1]\n  db = [\"a\"]\n  seed = 3\n}}\n");
        let expansion = expand_source(&source, "t.qsl");
        assert!(expansion.has_errors());
        let rendered = expansion.diags.render(&expansion.source, "t.qsl");
        assert!(rendered.contains("did you mean 'seed'?"), "{rendered}");
        assert!(rendered.contains("cannot vary 'db'"), "{rendered}");
        assert!(rendered.contains("must be a list of alternatives"), "{rendered}");
    }

    #[test]
    fn include_in_source_mode_is_an_error() {
        let source = "include \"base.qsl\"\n";
        let expansion = expand_source(source, "t.qsl");
        assert!(expansion.has_errors());
        let rendered = expansion.diags.render(&expansion.source, "t.qsl");
        assert!(rendered.contains("cannot load include"), "{rendered}");
    }

    #[test]
    fn includes_splice_and_compose() {
        let dir = tmp("splice");
        write(&dir, "base.qsl", BASE);
        let tenant = write(
            &dir,
            "tenant.qsl",
            "include \"base.qsl\"\noverride campaign { seed = 11 }\n",
        );
        let expansion = expand_path(&tenant).unwrap();
        assert!(!expansion.has_errors(), "{}", expansion.diags);
        assert_eq!(expansion.campaigns[0].campaign.seed, 11);
        assert!(expansion.source.contains("# >>> include \"base.qsl\""));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn include_cycles_are_typed_errors() {
        let dir = tmp("cycle");
        write(&dir, "a.qsl", "include \"b.qsl\"\n");
        let a = dir.join("a.qsl");
        write(&dir, "b.qsl", "include \"a.qsl\"\n");
        let err = expand_path(&a).unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        assert!(err.to_string().contains("include cycle"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_include_is_an_io_error() {
        let dir = tmp("missing");
        let spec = write(&dir, "spec.qsl", "include \"nope.qsl\"\n");
        let err = expand_path(&spec).unwrap_err();
        assert_eq!(err.kind(), "io");
        assert!(err.to_string().contains("nope.qsl"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unexpanded_constructs_are_rejected_by_plain_compile() {
        for source in [
            "include \"base.qsl\"\n",
            "override campaign { seed = 1 }\n",
            "matrix { seed = [1, 2] }\n",
        ] {
            let err = crate::spec::compile(source, "t.qsl").unwrap_err();
            assert!(err.to_string().contains("must be expanded"), "{err}");
        }
    }
}
