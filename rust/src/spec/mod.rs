//! QSL — the QADAM Spec Language: declarative campaign specs.
//!
//! A `*.qsl` file pins an entire DSE campaign as data: the hardware
//! sweep axes, the model-hyperparameter axes (`model_axes { width =
//! [...] depth = [...] }` — joint hardware × model co-exploration), the
//! search strategy, the workload (zoo models, custom layer stacks with
//! optional `accuracy { ... }` declarations, and `like`-derivations of
//! zoo models), and the persistence plan.
//! `qadam run campaign.qsl` executes it; `qadam validate campaign.qsl`
//! checks it and prints the resolved campaign; `qadam spec init` emits
//! a commented starter file.
//!
//! One spec can also expand into a *campaign set* for `qadam serve`:
//! `include "base.qsl"` splices a shared base, `override SECTION { .. }`
//! specializes it, and `matrix { key = [..] .. }` cross-products axes —
//! see the [`expand`] module.
//!
//! The front end is zero-dependency and hand-rolled in the house style:
//! a [`lexer`], a recovering recursive-descent [`parser`] producing a
//! spanned [`ast`], and a [`resolve`] pass that reports **all** problems
//! — with line/column spans, source excerpts, and "did you mean"
//! suggestions ([`diag`]) — before lowering into the framework's
//! existing campaign types ([`SweepSpec`](crate::arch::SweepSpec),
//! [`dnn::Model`](crate::dnn::Model), strategies, persistence paths).
//!
//! ```text
//! campaign { seed = 7 }
//! sweep {
//!     pe_type = [int16, lightpe1]
//!     array   = [8x8, 16x16]
//! }
//! strategy = random(8, seed = 11)
//! workload {
//!     dataset = cifar10
//!     models  = [resnet20, tiny]
//! }
//! model tiny {
//!     conv stem { in = 32, channels = 3, out = 16, kernel = 3, stride = 1, pad = 1 }
//!     pool p1   { in = 32, channels = 16, kernel = 2, stride = 2 }
//!     fc head   { in = 4096, out = 10 }
//! }
//! ```
//!
//! Lowering contract: a [`ResolvedCampaign`] is the meeting point of the
//! QSL front end and the flag-driven CLI — `qadam dse` builds one from
//! flags, `qadam run` from a spec — so equivalent invocations execute
//! the *same* code path and produce byte-identical artifacts. Every
//! campaign's canonical identity is fingerprinted (FNV-1a over
//! [`ResolvedCampaign::canonical_identity`]) into the checkpoint-journal
//! manifest, so resuming under an edited spec fails with
//! [`Error::InvalidConfig`](crate::Error::InvalidConfig) instead of
//! replaying points the edited campaign never selects.
//!
//! ```
//! use qadam::spec;
//!
//! let source = "sweep {\n  pe_type = [int16]\n  array = [8x8]\n}\n\
//!               workload {\n  dataset = cifar10\n  models = [resnet20]\n}\n";
//! let campaign = spec::compile(source, "demo.qsl")?;
//! // Omitted axes keep the paper's defaults; the set ones are pinned.
//! assert_eq!(campaign.sweep.pe_types.len(), 1);
//! assert_eq!(campaign.models()[0].name, "ResNet-20");
//! // The canonical form is a fixed point of parse → resolve → render.
//! let canonical = campaign.canonical();
//! let again = spec::compile(&canonical, "demo.qsl")?;
//! assert_eq!(again.canonical(), canonical);
//! # Ok::<(), qadam::Error>(())
//! ```

pub mod ast;
pub mod diag;
pub mod exec;
pub mod expand;
pub mod lexer;
pub mod lint;
pub mod parser;
pub mod resolve;

pub use diag::{Diagnostic, Diagnostics, Severity, Span};
pub use exec::{CacheOutcome, CampaignOutcome, FrontierOutcome, TraceOutcome};
pub use expand::{expand_path, expand_source, ExpandedCampaign, Expansion};
pub use lint::{Finding, Level, LintOptions, LintRule, RULES};
pub use resolve::{
    dataset_key, pe_key, zoo_key, PersistPlan, ResolvedCampaign, StrategyChoice, WorkloadModel,
    DATASET_KEYS, PE_KEYS, ZOO_KEYS,
};

use crate::error::Result;

/// Parse and resolve a spec, collecting every diagnostic. Returns the
/// resolved campaign only when no errors (warnings are fine) were
/// found — the `qadam validate` entry point.
pub fn check(source: &str) -> (Option<ResolvedCampaign>, Diagnostics) {
    let mut diags = Diagnostics::new();
    let file = parser::parse(source, &mut diags);
    let campaign = resolve::resolve(&file, &mut diags);
    (campaign, diags)
}

/// Parse and resolve a spec, or fail with a typed
/// [`Error::ParseError`](crate::Error::ParseError) carrying the full
/// rendered diagnostics — the `qadam run` entry point.
pub fn compile(source: &str, filename: &str) -> Result<ResolvedCampaign> {
    let (campaign, diags) = check(source);
    match campaign {
        Some(campaign) => Ok(campaign),
        None => Err(diags.into_error(source, filename)),
    }
}

/// The commented starter spec `qadam spec init` emits. Kept valid by
/// the test suite (it must always compile cleanly).
pub const STARTER_SPEC: &str = r#"# QADAM campaign spec (QSL).
# Run with:       qadam run campaign.qsl
# Check with:     qadam validate campaign.qsl
# Lint with:      qadam lint --deny all campaign.qsl
# Every section is optional; omitted fields take the same defaults as
# the `qadam dse` flags. This starter passes `qadam lint --deny all`
# out of the box: the exhaustive strategy cannot over-budget the space
# (rule Q002), and no persist block means no checkpoint-without-`every`
# hazard (rule Q010).

campaign {
    seed = 7          # synthesis-noise seed (determinism knob)
    workers = 0       # worker threads; 0 = all cores minus one
    # shard = 0 / 4   # run only this round-robin shard of the space
}

# Design-space axes. Omitted axes keep the paper's default space.
sweep {
    pe_type = [fp32, int16, lightpe1, lightpe2]
    array = [8x8, 16x16]
    glb_kib = [128]
    spad = [spad(12, 224, 24)]   # (ifmap, filter, psum) entries per PE
    dram_gbps = [8]
    clock_ghz = [2]
}

# Joint hardware x model co-exploration: sweep width/depth multipliers
# of every workload model against every hardware point.
# model_axes {
#     width = [0.5, 1]         # channel-width multipliers
#     depth = [1, 2]           # stride-1 convs repeated per multiplier
# }

# exhaustive (default), random(N[, seed = S]), or halving(KEEP[, rounds = R]).
strategy = exhaustive

workload {
    dataset = cifar10            # cifar10 | cifar100 | imagenet
    models = [vgg16, resnet20, resnet56]
    # Custom models defined below join the list by name.
}

# A custom model: an ordered conv/pool/fc stack. The optional accuracy
# block declares top-1 accuracies (percent) per precision, so Fig. 5/6
# accuracy fronts work for this model and its scaled variants.
# model tiny {
#     accuracy { int16 = 91.2, lightpe1 = 90.1 }
#     conv stem { in = 32, channels = 3, out = 16, kernel = 3, stride = 1, pad = 1 }
#     pool p1   { in = 32, channels = 16, kernel = 2, stride = 2 }
#     fc head   { in = 4096, out = 10 }
# }

# A derived model: start from a zoo model, override named layers.
# model wide20 like resnet20 {
#     layer fc { out = 10 }
# }

# Where to persist campaign artifacts (all optional).
# persist {
#     db = "out/db.json"              # evaluation database (dse --save)
#     cache = "out/cache.json"        # content-addressed point cache
#     checkpoint = "out/run.journal"  # resumable checkpoint journal
#     every = 16                      # journal flush interval
#     frontier = "out/frontier.json"  # streaming Pareto frontier
#     trace = "out/trace.json"        # deterministic event trace (+ .timing sidecar)
# }
"#;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::SweepSpec;
    use crate::dnn::Dataset;

    #[test]
    fn starter_spec_compiles_cleanly() {
        let (campaign, diags) = check(STARTER_SPEC);
        assert!(
            !diags.has_errors(),
            "starter spec must stay valid:\n{}",
            diags.render(STARTER_SPEC, "starter.qsl")
        );
        let campaign = campaign.unwrap();
        assert_eq!(campaign.dataset, Dataset::Cifar10);
        assert_eq!(campaign.workload.len(), 3);
        assert_eq!(campaign.sweep.len(), 4 * 2);
    }

    #[test]
    fn defaults_match_the_flag_path() {
        // An empty spec is the same campaign as bare `qadam dse`.
        let campaign = compile("", "empty.qsl").unwrap();
        assert_eq!(campaign.seed, 7);
        assert_eq!(campaign.workers, 0);
        assert_eq!(campaign.shard, (0, 1));
        assert_eq!(campaign.dataset, Dataset::Cifar10);
        assert_eq!(campaign.strategy, StrategyChoice::Exhaustive);
        assert_eq!(campaign.sweep.len(), SweepSpec::default().len());
        assert_eq!(campaign.models().len(), 3);
        assert!(campaign.persist.db.is_none());
    }

    #[test]
    fn canonical_is_a_fixed_point() {
        let source = "campaign {\n  seed = 11\n  shard = 1 / 3\n}\n\
                      sweep {\n  pe_type = [int16, lightpe1]\n  array = [8x8]\n  glb_kib = [64, 128]\n}\n\
                      strategy = random(5)\n\
                      workload {\n  dataset = cifar100\n  models = [resnet20, tiny]\n}\n\
                      model tiny {\n  conv c { in = 32, channels = 3, out = 8, kernel = 3 }\n  fc f { in = 2048, out = 100 }\n}\n\
                      persist {\n  db = \"out/db.json\"\n  checkpoint = \"out/j.journal\"\n}\n";
        let campaign = compile(source, "t.qsl").unwrap();
        let canonical = campaign.canonical();
        let reparsed = compile(&canonical, "t.canonical.qsl").unwrap();
        assert_eq!(reparsed.canonical(), canonical, "canonical must be a fixed point");
        assert_eq!(reparsed.fingerprint(), campaign.fingerprint());
        // The unseeded random() pinned the campaign seed.
        assert_eq!(campaign.strategy, StrategyChoice::Random { n: 5, seed: 11 });
    }

    #[test]
    fn fingerprint_ignores_transients_but_sees_identity() {
        let base = "sweep {\n  pe_type = [int16]\n  array = [8x8]\n}\n";
        let campaign = compile(base, "a.qsl").unwrap();
        // Workers and persistence are transient.
        let transient = format!(
            "campaign {{\n  workers = 9\n}}\n{base}persist {{\n  db = \"x.json\"\n}}\n"
        );
        let with_transients = compile(&transient, "b.qsl").unwrap();
        assert_eq!(campaign.fingerprint(), with_transients.fingerprint());
        // Seed, sweep, strategy, and models are identity.
        for edited in [
            format!("campaign {{\n  seed = 8\n}}\n{base}"),
            "sweep {\n  pe_type = [int16]\n  array = [16x16]\n}\n".to_string(),
            format!("{base}strategy = random(3)\n"),
            format!("{base}workload {{\n  models = [resnet20]\n}}\n"),
        ] {
            let other = compile(&edited, "c.qsl").unwrap();
            assert_ne!(campaign.fingerprint(), other.fingerprint(), "{edited}");
        }
    }

    #[test]
    fn all_errors_reported_in_one_pass_with_spans() {
        // Three distinct mistakes: a typo'd axis, an unknown PE type,
        // and an unknown model.
        let source = "sweep {\n  pe_typ = [int16]\n  pe_type = [int17]\n}\n\
                      workload {\n  models = [resnet21]\n}\n";
        let (campaign, diags) = check(source);
        assert!(campaign.is_none());
        assert!(diags.error_count() >= 3, "wanted >= 3 errors:\n{diags}");
        let rendered = diags.render(source, "bad.qsl");
        for needle in [
            "did you mean 'pe_type'?",
            "did you mean 'int16'?",
            "did you mean 'resnet20'?",
            "bad.qsl:2:3",
            "bad.qsl:3:14",
            "bad.qsl:6:13",
        ] {
            assert!(rendered.contains(needle), "missing {needle} in:\n{rendered}");
        }
    }

    #[test]
    fn like_models_override_layers() {
        let source = "workload {\n  dataset = cifar100\n  models = [wide]\n}\n\
                      model wide like resnet20 {\n  layer fc { out = 100 }\n}\n";
        let campaign = compile(source, "t.qsl").unwrap();
        let models = campaign.models();
        assert_eq!(models[0].name, "wide");
        let fc = models[0].layers.last().unwrap();
        assert_eq!(fc.out_c, 100);
        // Everything else matches the zoo base.
        let base = crate::dnn::model_for(crate::dnn::ModelKind::ResNet20, Dataset::Cifar100);
        assert_eq!(models[0].layers.len(), base.layers.len());
    }

    #[test]
    fn impossible_geometry_is_rejected() {
        let source = "workload {\n  models = [bad]\n}\n\
                      model bad {\n  conv c { in = 4, channels = 3, out = 8, kernel = 9 }\n}\n";
        let (campaign, diags) = check(source);
        assert!(campaign.is_none());
        let rendered = diags.render(source, "t.qsl");
        assert!(rendered.contains("kernel 9 exceeds the padded input"), "{rendered}");
    }

    #[test]
    fn zoo_dataset_mismatch_is_rejected() {
        let source = "workload {\n  dataset = imagenet\n  models = [resnet20]\n}\n";
        let (campaign, diags) = check(source);
        assert!(campaign.is_none());
        let rendered = diags.render(source, "t.qsl");
        assert!(rendered.contains("not defined for dataset 'imagenet'"), "{rendered}");
    }

    #[test]
    fn unused_model_warns_but_compiles() {
        let source = "model spare {\n  fc f { in = 8, out = 2 }\n}\n";
        let (campaign, diags) = check(source);
        assert!(campaign.is_some());
        assert!(!diags.has_errors());
        assert_eq!(diags.len(), 1, "{diags}");
    }

    #[test]
    fn compile_error_carries_rendered_diagnostics() {
        let err = compile("sweep {\n  glb_kib = [0]\n}\n", "z.qsl").unwrap_err();
        assert_eq!(err.kind(), "parse_error");
        let text = err.to_string();
        assert!(text.contains("z.qsl:2:14"), "{text}");
        assert!(text.contains("must be at least 1"), "{text}");
    }
}
