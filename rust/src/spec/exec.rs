//! Campaign execution: one runner for spec-driven and flag-driven DSE.
//!
//! [`ResolvedCampaign::execute`] wires an [`Explorer`] exactly the way
//! `qadam dse` always has — strategy, shard, point cache, checkpoint
//! journal, streaming frontier, database save — so `qadam run spec.qsl`
//! and the equivalent flag invocation produce byte-identical artifacts
//! (they are literally the same code path). The campaign's QSL
//! [`fingerprint`](ResolvedCampaign::fingerprint) is pinned into the
//! journal manifest via [`Explorer::campaign_fingerprint`], which is
//! how resuming under an edited spec is rejected.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use super::resolve::ResolvedCampaign;
use crate::error::Result;
use crate::explore::{lock_shared, EvalDatabase, Explorer, PointCache};
use crate::pareto::CampaignFrontier;

/// What a cache-backed campaign did to its cache.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheOutcome {
    /// Where the cache was saved.
    pub path: PathBuf,
    /// Cached design points after the campaign.
    pub entries: usize,
    /// Lookups served from the cache during this run.
    pub hits: u64,
    /// Lookups that missed during this run.
    pub misses: u64,
}

/// What a frontier-tracking campaign archived.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierOutcome {
    /// Where the frontier was saved.
    pub path: PathBuf,
    /// Per-model `(name, front size)` in workload order.
    pub per_model: Vec<(String, usize)>,
}

/// The artifacts of one executed campaign.
#[derive(Debug, Clone)]
pub struct CampaignOutcome {
    /// The evaluation database (also saved to `persist.db` when set).
    pub db: EvalDatabase,
    /// Where the database was saved, when `persist.db` was set.
    pub saved_db: Option<PathBuf>,
    /// Cache statistics, when `persist.cache` was set.
    pub cache: Option<CacheOutcome>,
    /// Frontier statistics, when `persist.frontier` was set.
    pub frontier: Option<FrontierOutcome>,
}

impl ResolvedCampaign {
    /// Build the campaign's [`Explorer`] (joint space — hardware sweep
    /// × model axes — models, seed, workers, shard, strategy,
    /// fingerprint) without any persistence wiring — the
    /// embedding-friendly entry point.
    pub fn explorer(&self) -> Explorer {
        let space =
            crate::arch::DesignSpace::new(self.sweep.clone(), self.model_axes.clone());
        let explorer = Explorer::over(space)
            .dataset(self.dataset)
            .models(self.models())
            .workers(self.workers)
            .seed(self.seed)
            .shard(self.shard.0, self.shard.1)
            .campaign_fingerprint(self.fingerprint());
        self.strategy.attach(explorer)
    }

    /// The user-declared accuracy book of this campaign: declared
    /// entries for custom models merged over the paper registry (see
    /// [`crate::accuracy::AccuracyBook`]) — what the Fig. 5/6-style
    /// accuracy fronts consult for custom and scaled model variants.
    pub fn accuracy_book(&self) -> crate::accuracy::AccuracyBook {
        let mut book = crate::accuracy::AccuracyBook::new();
        for (model, entries) in &self.accuracy {
            for &(pe, top1) in entries {
                book.declare(model, pe, top1);
            }
        }
        book
    }

    /// Run the campaign end to end: attach the persistence plan (cache,
    /// checkpoint journal, frontier), evaluate, and save every artifact
    /// the plan names. Identical campaigns produce byte-identical
    /// artifacts regardless of whether they came from a spec file or
    /// from CLI flags.
    pub fn execute(&self) -> Result<CampaignOutcome> {
        let mut explorer = self.explorer();
        let frontier = self
            .persist
            .frontier
            .as_ref()
            .map(|_| Arc::new(Mutex::new(CampaignFrontier::new())));
        if let Some(frontier) = &frontier {
            explorer = explorer.frontier(frontier.clone());
        }
        if let Some(path) = &self.persist.checkpoint {
            explorer = explorer.checkpoint(path, self.persist.every);
        }
        let cache = match &self.persist.cache {
            None => None,
            Some(path) => {
                let loaded =
                    if path.exists() { PointCache::load(path)? } else { PointCache::new() };
                Some(Arc::new(Mutex::new(loaded)))
            }
        };
        if let Some(cache) = &cache {
            explorer = explorer.cache(cache.clone());
        }
        let db = explorer.run()?;
        let cache_outcome = match (&cache, &self.persist.cache) {
            (Some(cache), Some(path)) => {
                let cache = lock_shared(cache);
                cache.save(path)?;
                Some(CacheOutcome {
                    path: path.clone(),
                    entries: cache.len(),
                    hits: cache.hits(),
                    misses: cache.misses(),
                })
            }
            _ => None,
        };
        let frontier_outcome = match (&frontier, &self.persist.frontier) {
            (Some(frontier), Some(path)) => {
                let frontier = lock_shared(frontier);
                frontier.save(path)?;
                Some(FrontierOutcome {
                    path: path.clone(),
                    per_model: frontier
                        .models()
                        .iter()
                        .map(|m| (m.model_name().to_string(), m.front().len()))
                        .collect(),
                })
            }
            _ => None,
        };
        let saved_db = match &self.persist.db {
            Some(path) => {
                db.save(path)?;
                Some(path.clone())
            }
            None => None,
        };
        Ok(CampaignOutcome { db, saved_db, cache: cache_outcome, frontier: frontier_outcome })
    }
}
