//! Campaign execution: one runner for spec-driven and flag-driven DSE.
//!
//! [`ResolvedCampaign::execute`] wires an [`Explorer`] exactly the way
//! `qadam dse` always has — strategy, shard, point cache, checkpoint
//! journal, streaming frontier, database save — so `qadam run spec.qsl`
//! and the equivalent flag invocation produce byte-identical artifacts
//! (they are literally the same code path). The campaign's QSL
//! [`fingerprint`](ResolvedCampaign::fingerprint) is pinned into the
//! journal manifest via [`Explorer::campaign_fingerprint`], which is
//! how resuming under an edited spec is rejected.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use super::resolve::ResolvedCampaign;
use crate::error::Result;
use crate::explore::{lock_shared, EvalDatabase, Explorer, PointCache};
use crate::obs::{self, TraceRecorder};
use crate::pareto::CampaignFrontier;

/// What a cache-backed campaign did to its cache.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheOutcome {
    /// Where the cache was saved.
    pub path: PathBuf,
    /// Cached design points after the campaign.
    pub entries: usize,
    /// Lookups served from the cache during this run.
    ///
    /// Per-run delta: the cache's lifetime counters persist across
    /// save/load, so this subtracts the count the cache arrived with.
    pub hits: u64,
    /// Lookups that missed during this run (per-run delta, like
    /// [`hits`](Self::hits)).
    pub misses: u64,
    /// The cache lineage's save generation after this campaign saved it
    /// (1 for a cache born this run).
    pub generation: u64,
}

/// What a traced campaign recorded.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceOutcome {
    /// Where the deterministic event trace (`qadam.trace`) was saved.
    pub path: PathBuf,
    /// Events in the trace.
    pub events: usize,
    /// Where the wall-clock timing sidecar (`qadam.timing`) was saved —
    /// always `<path>.timing`, and never consulted by determinism
    /// checks.
    pub timing: PathBuf,
}

/// What a frontier-tracking campaign archived.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierOutcome {
    /// Where the frontier was saved.
    pub path: PathBuf,
    /// Per-model `(name, front size)` in workload order.
    pub per_model: Vec<(String, usize)>,
}

/// The artifacts of one executed campaign.
#[derive(Debug, Clone)]
pub struct CampaignOutcome {
    /// The evaluation database (also saved to `persist.db` when set).
    pub db: EvalDatabase,
    /// Where the database was saved, when `persist.db` was set.
    pub saved_db: Option<PathBuf>,
    /// Cache statistics, when `persist.cache` was set.
    pub cache: Option<CacheOutcome>,
    /// Frontier statistics, when `persist.frontier` was set.
    pub frontier: Option<FrontierOutcome>,
    /// Trace artifacts, when `persist.trace` was set.
    pub trace: Option<TraceOutcome>,
}

impl ResolvedCampaign {
    /// Build the campaign's [`Explorer`] (joint space — hardware sweep
    /// × model axes — models, seed, workers, shard, strategy,
    /// fingerprint) without any persistence wiring — the
    /// embedding-friendly entry point.
    pub fn explorer(&self) -> Explorer {
        let space =
            crate::arch::DesignSpace::new(self.sweep.clone(), self.model_axes.clone());
        let explorer = Explorer::over(space)
            .dataset(self.dataset)
            .models(self.models())
            .workers(self.workers)
            .seed(self.seed)
            .shard(self.shard.0, self.shard.1)
            .campaign_fingerprint(self.fingerprint());
        self.strategy.attach(explorer)
    }

    /// The user-declared accuracy book of this campaign: declared
    /// entries for custom models merged over the paper registry (see
    /// [`crate::accuracy::AccuracyBook`]) — what the Fig. 5/6-style
    /// accuracy fronts consult for custom and scaled model variants.
    pub fn accuracy_book(&self) -> crate::accuracy::AccuracyBook {
        let mut book = crate::accuracy::AccuracyBook::new();
        for (model, entries) in &self.accuracy {
            for &(pe, top1) in entries {
                book.declare(model, pe, top1);
            }
        }
        book
    }

    /// Run the campaign end to end: attach the persistence plan (cache,
    /// checkpoint journal, frontier), evaluate, and save every artifact
    /// the plan names. Identical campaigns produce byte-identical
    /// artifacts regardless of whether they came from a spec file or
    /// from CLI flags.
    pub fn execute(&self) -> Result<CampaignOutcome> {
        self.execute_with(&self.persist, None)
    }

    /// [`Self::execute`] against an explicit persistence plan, with an
    /// optional *shared* point cache.
    ///
    /// `qadam serve` runs every campaign of a batch through here: the
    /// plan names per-campaign artifact paths under the batch output
    /// directory, and `shared_cache` is the batch-wide
    /// `Arc<Mutex<PointCache>>` that dedupes overlapping evaluations
    /// across campaigns. When a shared cache is passed, this method
    /// neither loads nor saves `plan.cache` (the scheduler owns the
    /// shared cache's persistence — saving it per campaign under the
    /// campaign's own lock scope would interleave with other tenants),
    /// so the returned outcome's `cache` field is `None`; the scheduler
    /// computes per-campaign hit/miss deltas from counter snapshots
    /// around the run.
    pub fn execute_with(
        &self,
        plan: &super::resolve::PersistPlan,
        shared_cache: Option<Arc<Mutex<PointCache>>>,
    ) -> Result<CampaignOutcome> {
        let mut explorer = self.explorer();
        let frontier =
            plan.frontier.as_ref().map(|_| Arc::new(Mutex::new(CampaignFrontier::new())));
        if let Some(frontier) = &frontier {
            explorer = explorer.frontier(frontier.clone());
        }
        if let Some(path) = &plan.checkpoint {
            explorer = explorer.checkpoint(path, plan.every);
        }
        let shared = shared_cache.is_some();
        let cache = match (&shared_cache, &plan.cache) {
            (Some(cache), _) => Some(cache.clone()),
            (None, Some(path)) => {
                let loaded =
                    if path.exists() { PointCache::load(path)? } else { PointCache::new() };
                Some(Arc::new(Mutex::new(loaded)))
            }
            (None, None) => None,
        };
        if let Some(cache) = &cache {
            explorer = explorer.cache(cache.clone());
        }
        // Lifetime counters persist across save/load, so snapshot the
        // warm baseline now and report per-run deltas below.
        let warm = cache
            .as_ref()
            .map(|cache| {
                let shared = lock_shared(cache);
                (shared.hits(), shared.misses())
            })
            .unwrap_or((0, 0));
        let recorder = plan.trace.as_ref().map(|_| Arc::new(TraceRecorder::new()));
        if let Some(recorder) = &recorder {
            explorer = explorer.trace_sink(recorder.clone());
        }
        let db = explorer.run()?;
        let cache_outcome = match (&cache, &plan.cache) {
            (Some(cache), Some(path)) if !shared => {
                let mut cache = lock_shared(cache);
                cache.save(path)?;
                Some(CacheOutcome {
                    path: path.clone(),
                    entries: cache.len(),
                    hits: cache.hits() - warm.0,
                    misses: cache.misses() - warm.1,
                    generation: cache.generation(),
                })
            }
            _ => None,
        };
        let trace_outcome = match (&recorder, &plan.trace) {
            (Some(recorder), Some(path)) => {
                let (trace, timing) = recorder.snapshot();
                trace.save(path)?;
                let sidecar = obs::sidecar_path(path);
                timing.save(&sidecar)?;
                Some(TraceOutcome { path: path.clone(), events: trace.len(), timing: sidecar })
            }
            _ => None,
        };
        let frontier_outcome = match (&frontier, &plan.frontier) {
            (Some(frontier), Some(path)) => {
                let frontier = lock_shared(frontier);
                frontier.save(path)?;
                Some(FrontierOutcome {
                    path: path.clone(),
                    per_model: frontier
                        .models()
                        .iter()
                        .map(|m| (m.model_name().to_string(), m.front().len()))
                        .collect(),
                })
            }
            _ => None,
        };
        let saved_db = match &plan.db {
            Some(path) => {
                db.save_auto(path)?;
                Some(path.clone())
            }
            None => None,
        };
        Ok(CampaignOutcome {
            db,
            saved_db,
            cache: cache_outcome,
            frontier: frontier_outcome,
            trace: trace_outcome,
        })
    }
}
