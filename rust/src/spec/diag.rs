//! Span-carrying diagnostics for the QSL front end.
//!
//! Every lexer, parser, and resolver complaint is a [`Diagnostic`]: a
//! severity, a message, a byte-offset [`Span`] into the source, and an
//! optional `help` line (usually a "did you mean" suggestion from
//! [`crate::util::text::did_you_mean`]). The front end *collects* —
//! a broken spec reports every problem in one pass, not just the first —
//! and [`Diagnostics::render`] turns the batch into the rustc-style
//! excerpt format the golden diagnostics fixtures pin byte-for-byte:
//!
//! ```text
//! error: unknown sweep axis 'pe_typ'
//!   --> campaign.qsl:4:3
//!    |
//!  4 |   pe_typ = [int16]
//!    |   ^^^^^^
//!    = help: did you mean 'pe_type'?
//! ```

use std::fmt;

/// Half-open byte range `[start, end)` into the spec source.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// Byte offset of the first spanned byte.
    pub start: usize,
    /// Byte offset one past the last spanned byte.
    pub end: usize,
}

impl Span {
    /// A span covering `[start, end)`.
    pub fn new(start: usize, end: usize) -> Self {
        Self { start, end: end.max(start) }
    }

    /// A zero-width span at `pos` (for end-of-input diagnostics).
    pub fn at(pos: usize) -> Self {
        Self { start: pos, end: pos }
    }

    /// The smallest span covering both `self` and `other`.
    pub fn join(self, other: Span) -> Span {
        Span { start: self.start.min(other.start), end: self.end.max(other.end) }
    }
}

/// How bad a diagnostic is. Errors fail validation; warnings do not.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// The spec cannot be lowered.
    Error,
    /// Suspicious but lowerable (e.g. an unused model definition).
    Warning,
}

impl Severity {
    /// Rendering label (`error` / `warning`).
    pub fn label(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

/// One located complaint about a spec.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    /// Error or warning.
    pub severity: Severity,
    /// What is wrong, phrased against the source text.
    pub message: String,
    /// Where in the source it is wrong.
    pub span: Span,
    /// Optional fix-it line (rendered as `= help: ...`).
    pub help: Option<String>,
}

/// An ordered batch of diagnostics — the QSL front end's error channel.
///
/// Parsing and resolving never stop at the first problem; they push into
/// this collection and keep going, so `qadam validate` reports a broken
/// spec's mistakes all at once.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Diagnostics {
    diags: Vec<Diagnostic>,
}

impl Diagnostics {
    /// An empty collection.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an error.
    pub fn error(&mut self, span: Span, message: impl Into<String>) {
        self.diags.push(Diagnostic {
            severity: Severity::Error,
            message: message.into(),
            span,
            help: None,
        });
    }

    /// Record an error with a help line.
    pub fn error_help(&mut self, span: Span, message: impl Into<String>, help: impl Into<String>) {
        self.diags.push(Diagnostic {
            severity: Severity::Error,
            message: message.into(),
            span,
            help: Some(help.into()),
        });
    }

    /// Record a warning.
    pub fn warn(&mut self, span: Span, message: impl Into<String>) {
        self.diags.push(Diagnostic {
            severity: Severity::Warning,
            message: message.into(),
            span,
            help: None,
        });
    }

    /// Record a warning with a fix-it help line.
    pub fn warn_help(&mut self, span: Span, message: impl Into<String>, help: impl Into<String>) {
        self.diags.push(Diagnostic {
            severity: Severity::Warning,
            message: message.into(),
            span,
            help: Some(help.into()),
        });
    }

    /// All diagnostics in emission order.
    pub fn iter(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diags.iter()
    }

    /// Number of diagnostics (errors + warnings).
    pub fn len(&self) -> usize {
        self.diags.len()
    }

    /// Whether nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.diags.is_empty()
    }

    /// Absorb every diagnostic from `other` (used by the expansion pass
    /// to merge per-combination resolver batches).
    pub fn extend(&mut self, other: Diagnostics) {
        self.diags.extend(other.diags);
    }

    /// The number of error-severity diagnostics recorded.
    pub fn error_count(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Error).count()
    }

    /// Whether any error-severity diagnostic was recorded.
    pub fn has_errors(&self) -> bool {
        self.error_count() > 0
    }

    /// Render the whole batch against its source, rustc-style: one block
    /// per diagnostic (message, `--> file:line:col`, source excerpt with
    /// a caret underline, optional help), then a summary line. The output
    /// is deterministic, so golden tests pin it byte-for-byte.
    pub fn render(&self, source: &str, filename: &str) -> String {
        let lines = SourceLines::new(source);
        let mut out = String::new();
        for diag in &self.diags {
            out.push_str(&render_one(diag, source, filename, &lines));
            out.push('\n');
        }
        let errors = self.error_count();
        let warnings = self.len() - errors;
        match (errors, warnings) {
            (0, 0) => {}
            (0, w) => out.push_str(&format!("{w} warning(s) emitted\n")),
            (e, 0) => out.push_str(&format!("{e} error(s) emitted\n")),
            (e, w) => out.push_str(&format!("{e} error(s), {w} warning(s) emitted\n")),
        }
        out
    }

    /// Collapse the batch into the crate-wide typed error: the full
    /// rendering inside [`Error::ParseError`](crate::Error::ParseError).
    pub fn into_error(self, source: &str, filename: &str) -> crate::Error {
        crate::Error::ParseError(format!(
            "{filename} is not a valid campaign spec\n{}",
            self.render(source, filename)
        ))
    }
}

impl fmt::Display for Diagnostics {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for diag in &self.diags {
            writeln!(f, "{}: {}", diag.severity.label(), diag.message)?;
        }
        Ok(())
    }
}

/// 1-based `(line, column)` of a byte offset in `source`, counting
/// columns in characters — the same coordinates the rendered
/// diagnostics print, exposed for machine-readable consumers (the
/// `qadam lint --format json` output).
pub fn locate(source: &str, offset: usize) -> (usize, usize) {
    SourceLines::new(source).locate(source, offset)
}

/// Byte offsets of line starts, for O(log n) offset → (line, col) lookup.
struct SourceLines {
    starts: Vec<usize>,
}

impl SourceLines {
    fn new(source: &str) -> Self {
        let mut starts = vec![0usize];
        for (i, b) in source.bytes().enumerate() {
            if b == b'\n' {
                starts.push(i + 1);
            }
        }
        Self { starts }
    }

    /// 1-based (line, column) of a byte offset; columns count characters.
    fn locate(&self, source: &str, offset: usize) -> (usize, usize) {
        let offset = offset.min(source.len());
        let line_idx = match self.starts.binary_search(&offset) {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        let line_start = self.starts[line_idx];
        let col = source[line_start..offset].chars().count() + 1;
        (line_idx + 1, col)
    }

    /// The full text of a 1-based line, without its newline.
    fn line_text<'s>(&self, source: &'s str, line: usize) -> &'s str {
        let start = self.starts[line - 1];
        let end = self
            .starts
            .get(line)
            .map(|next| next - 1) // strip the '\n'
            .unwrap_or(source.len());
        source[start..end].trim_end_matches('\r')
    }
}

fn render_one(diag: &Diagnostic, source: &str, filename: &str, lines: &SourceLines) -> String {
    let (line, col) = lines.locate(source, diag.span.start);
    let text = lines.line_text(source, line);
    // Caret length: the spanned characters, clamped to the first line.
    let line_start = lines.starts[line - 1];
    let span_on_line_end = diag.span.end.min(line_start + text.len()).max(diag.span.start);
    let caret_len = source[diag.span.start..span_on_line_end].chars().count().max(1);
    let gutter = format!("{line}");
    let pad = " ".repeat(gutter.len());
    let mut out = format!(
        "{}: {}\n{pad}--> {filename}:{line}:{col}\n{pad} |\n{gutter} | {text}\n{pad} | {}{}\n",
        diag.severity.label(),
        diag.message,
        " ".repeat(col - 1),
        "^".repeat(caret_len),
    );
    if let Some(help) = &diag.help {
        out.push_str(&format!("{pad} = help: {help}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_join_and_clamp() {
        let a = Span::new(3, 5);
        let b = Span::new(8, 10);
        assert_eq!(a.join(b), Span::new(3, 10));
        // end < start clamps to empty-at-start.
        assert_eq!(Span::new(7, 2), Span { start: 7, end: 7 });
    }

    #[test]
    fn renders_line_col_excerpt_and_help() {
        let source = "sweep {\n  pe_typ = [int16]\n}\n";
        let mut diags = Diagnostics::new();
        let start = source.find("pe_typ").unwrap();
        diags.error_help(
            Span::new(start, start + 6),
            "unknown sweep axis 'pe_typ'",
            "did you mean 'pe_type'?",
        );
        let rendered = diags.render(source, "campaign.qsl");
        assert!(rendered.contains("error: unknown sweep axis 'pe_typ'"), "{rendered}");
        assert!(rendered.contains("--> campaign.qsl:2:3"), "{rendered}");
        assert!(rendered.contains("2 |   pe_typ = [int16]"), "{rendered}");
        assert!(rendered.contains("  |   ^^^^^^"), "{rendered}");
        assert!(rendered.contains("= help: did you mean 'pe_type'?"), "{rendered}");
        assert!(rendered.contains("1 error(s) emitted"), "{rendered}");
    }

    #[test]
    fn reports_every_diagnostic_not_just_the_first() {
        let source = "a\nbb\nccc\n";
        let mut diags = Diagnostics::new();
        diags.error(Span::new(0, 1), "first");
        diags.warn(Span::new(2, 4), "second");
        diags.error(Span::new(5, 8), "third");
        assert_eq!(diags.error_count(), 2);
        let rendered = diags.render(source, "x.qsl");
        for needle in ["first", "second", "third", "x.qsl:1:1", "x.qsl:2:1", "x.qsl:3:1"] {
            assert!(rendered.contains(needle), "missing {needle} in:\n{rendered}");
        }
        assert!(rendered.contains("2 error(s), 1 warning(s) emitted"), "{rendered}");
    }

    #[test]
    fn end_of_input_span_renders_cleanly() {
        let source = "campaign {";
        let mut diags = Diagnostics::new();
        diags.error(Span::at(source.len()), "expected '}'");
        let rendered = diags.render(source, "f.qsl");
        assert!(rendered.contains("f.qsl:1:11"), "{rendered}");
        assert!(rendered.contains('^'), "{rendered}");
    }
}
