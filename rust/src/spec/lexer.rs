//! Hand-rolled lexer for QSL source text.
//!
//! Produces a flat [`Token`] stream with byte [`Span`]s. Lexing never
//! aborts: malformed input (unterminated strings, stray characters)
//! is reported into the shared [`Diagnostics`] batch and skipped, so
//! the parser still sees the rest of the file and can report *its*
//! problems too.
//!
//! Newline handling: QSL statements are line-oriented, so the lexer
//! emits collapsed [`Tok::Newline`] tokens — except inside `[...]` and
//! `(...)`, where lists may wrap freely across lines.

use super::diag::{Diagnostics, Span};

/// Token kind.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    /// Identifier / bare word: `[A-Za-z_][A-Za-z0-9_]*`.
    Ident(String),
    /// Numeric literal (integers and floats share a representation,
    /// exactly like the JSON substrate).
    Num(f64),
    /// Array-dimension literal `RxC`, e.g. `16x16`.
    Dims(usize, usize),
    /// Double-quoted string literal (supports `\"`, `\\`, `\n`, `\t`).
    Str(String),
    /// `{`
    LBrace,
    /// `}`
    RBrace,
    /// `[`
    LBracket,
    /// `]`
    RBracket,
    /// `(`
    LParen,
    /// `)`
    RParen,
    /// `,`
    Comma,
    /// `=`
    Eq,
    /// `/` (shard designators: `0 / 4`)
    Slash,
    /// One or more line breaks (collapsed; suppressed inside `[ ]`/`( )`).
    Newline,
    /// End of input (always the final token).
    Eof,
}

impl Tok {
    /// Human-readable name for "expected X, found Y" diagnostics.
    pub fn describe(&self) -> String {
        match self {
            Tok::Ident(w) => format!("'{w}'"),
            Tok::Num(n) => format!("number {}", fmt_num(*n)),
            Tok::Dims(r, c) => format!("dimensions {r}x{c}"),
            Tok::Str(_) => "string".into(),
            Tok::LBrace => "'{'".into(),
            Tok::RBrace => "'}'".into(),
            Tok::LBracket => "'['".into(),
            Tok::RBracket => "']'".into(),
            Tok::LParen => "'('".into(),
            Tok::RParen => "')'".into(),
            Tok::Comma => "','".into(),
            Tok::Eq => "'='".into(),
            Tok::Slash => "'/'".into(),
            Tok::Newline => "end of line".into(),
            Tok::Eof => "end of file".into(),
        }
    }
}

/// Render a number the way the canonical form does (shortest form,
/// integers without a fraction).
pub fn fmt_num(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 9e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

/// A token with its source span.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    /// Token kind.
    pub tok: Tok,
    /// Source bytes this token covers.
    pub span: Span,
}

/// Lex a whole QSL document. Problems are pushed into `diags`; the
/// returned stream always ends with a [`Tok::Eof`] token.
pub fn lex(source: &str, diags: &mut Diagnostics) -> Vec<Token> {
    let bytes = source.as_bytes();
    let mut tokens: Vec<Token> = Vec::new();
    let mut pos = 0usize;
    // `[`/`(` nesting depth; newlines inside are soft (suppressed).
    let mut wrap_depth = 0usize;
    while pos < bytes.len() {
        let start = pos;
        let b = bytes[pos];
        match b {
            b' ' | b'\t' | b'\r' => pos += 1,
            b'#' => {
                while pos < bytes.len() && bytes[pos] != b'\n' {
                    pos += 1;
                }
            }
            b'\n' => {
                pos += 1;
                if wrap_depth == 0 && !matches!(tokens.last().map(|t| &t.tok), Some(Tok::Newline)) {
                    tokens.push(Token { tok: Tok::Newline, span: Span::new(start, pos) });
                }
            }
            b'{' => {
                pos += 1;
                tokens.push(Token { tok: Tok::LBrace, span: Span::new(start, pos) });
            }
            b'}' => {
                pos += 1;
                tokens.push(Token { tok: Tok::RBrace, span: Span::new(start, pos) });
            }
            b'[' => {
                pos += 1;
                wrap_depth += 1;
                tokens.push(Token { tok: Tok::LBracket, span: Span::new(start, pos) });
            }
            b']' => {
                pos += 1;
                wrap_depth = wrap_depth.saturating_sub(1);
                tokens.push(Token { tok: Tok::RBracket, span: Span::new(start, pos) });
            }
            b'(' => {
                pos += 1;
                wrap_depth += 1;
                tokens.push(Token { tok: Tok::LParen, span: Span::new(start, pos) });
            }
            b')' => {
                pos += 1;
                wrap_depth = wrap_depth.saturating_sub(1);
                tokens.push(Token { tok: Tok::RParen, span: Span::new(start, pos) });
            }
            b',' => {
                pos += 1;
                tokens.push(Token { tok: Tok::Comma, span: Span::new(start, pos) });
            }
            b'=' => {
                pos += 1;
                tokens.push(Token { tok: Tok::Eq, span: Span::new(start, pos) });
            }
            b'/' => {
                pos += 1;
                tokens.push(Token { tok: Tok::Slash, span: Span::new(start, pos) });
            }
            b'"' => {
                let (text, new_pos, ok) = lex_string(source, pos);
                if !ok {
                    diags.error(Span::new(start, new_pos), "unterminated string literal");
                }
                tokens.push(Token { tok: Tok::Str(text), span: Span::new(start, new_pos) });
                pos = new_pos;
            }
            b'0'..=b'9' | b'-' => {
                let (tok, new_pos) = lex_number(source, pos, diags);
                tokens.push(Token { tok, span: Span::new(start, new_pos) });
                pos = new_pos;
            }
            b'A'..=b'Z' | b'a'..=b'z' | b'_' => {
                while pos < bytes.len()
                    && (bytes[pos].is_ascii_alphanumeric() || bytes[pos] == b'_')
                {
                    pos += 1;
                }
                tokens.push(Token {
                    tok: Tok::Ident(source[start..pos].to_string()),
                    span: Span::new(start, pos),
                });
            }
            _ => {
                // Skip one whole UTF-8 character, not one byte.
                let ch = source[pos..].chars().next().unwrap_or('?');
                pos += ch.len_utf8();
                diags.error(
                    Span::new(start, pos),
                    format!("unexpected character '{ch}' in spec"),
                );
            }
        }
    }
    // A trailing statement without a newline still needs a terminator.
    if !matches!(tokens.last().map(|t| &t.tok), Some(Tok::Newline) | None) {
        tokens.push(Token { tok: Tok::Newline, span: Span::at(source.len()) });
    }
    tokens.push(Token { tok: Tok::Eof, span: Span::at(source.len()) });
    tokens
}

/// Lex a string literal starting at the opening quote. Returns the
/// decoded text, the position after the closing quote (or the line/file
/// end on an unterminated literal), and whether it terminated.
fn lex_string(source: &str, open: usize) -> (String, usize, bool) {
    let bytes = source.as_bytes();
    let mut out = String::new();
    let mut pos = open + 1;
    while pos < bytes.len() {
        match bytes[pos] {
            b'"' => return (out, pos + 1, true),
            b'\n' => return (out, pos, false),
            b'\\' => {
                // Advance at char granularity: the escaped character may
                // be multi-byte, and landing mid-character would make the
                // next iteration's slicing panic.
                match source[pos + 1..].chars().next() {
                    None => return (out, bytes.len(), false),
                    // A backslash at end-of-line: unterminated, and the
                    // newline stays outside the string.
                    Some('\n') => return (out, pos + 1, false),
                    Some(ch) => {
                        pos += 1 + ch.len_utf8();
                        match ch {
                            '"' => out.push('"'),
                            '\\' => out.push('\\'),
                            'n' => out.push('\n'),
                            't' => out.push('\t'),
                            // Unknown escape: keep it verbatim; the
                            // resolver treats paths as opaque strings.
                            other => {
                                out.push('\\');
                                out.push(other);
                            }
                        }
                    }
                }
            }
            b if b < 0x80 => {
                out.push(b as char);
                pos += 1;
            }
            _ => {
                let ch = source[pos..].chars().next().unwrap_or('?');
                out.push(ch);
                pos += ch.len_utf8();
            }
        }
    }
    (out, pos, false)
}

/// Lex a number or an `RxC` dims literal starting at `start`.
fn lex_number(source: &str, start: usize, diags: &mut Diagnostics) -> (Tok, usize) {
    let bytes = source.as_bytes();
    let mut pos = start;
    if bytes[pos] == b'-' {
        pos += 1;
    }
    let int_start = pos;
    while pos < bytes.len() && bytes[pos].is_ascii_digit() {
        pos += 1;
    }
    // Dims literal: digits immediately followed by `x` and more digits
    // (only for unsigned integers, e.g. `16x16`).
    if bytes[start] != b'-'
        && pos > int_start
        && pos < bytes.len()
        && bytes[pos] == b'x'
        && bytes.get(pos + 1).is_some_and(u8::is_ascii_digit)
    {
        let rows: usize = source[start..pos].parse().unwrap_or(0);
        let col_start = pos + 1;
        let mut col_end = col_start;
        while col_end < bytes.len() && bytes[col_end].is_ascii_digit() {
            col_end += 1;
        }
        let cols: usize = source[col_start..col_end].parse().unwrap_or(0);
        return (Tok::Dims(rows, cols), col_end);
    }
    if pos < bytes.len() && bytes[pos] == b'.' {
        pos += 1;
        while pos < bytes.len() && bytes[pos].is_ascii_digit() {
            pos += 1;
        }
    }
    if pos < bytes.len() && (bytes[pos] == b'e' || bytes[pos] == b'E') {
        pos += 1;
        if pos < bytes.len() && (bytes[pos] == b'+' || bytes[pos] == b'-') {
            pos += 1;
        }
        while pos < bytes.len() && bytes[pos].is_ascii_digit() {
            pos += 1;
        }
    }
    match source[start..pos].parse::<f64>() {
        Ok(x) => (Tok::Num(x), pos),
        Err(_) => {
            diags.error(
                Span::new(start, pos.max(start + 1)),
                format!("malformed number '{}'", &source[start..pos.max(start + 1)]),
            );
            (Tok::Num(0.0), pos.max(start + 1))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(source: &str) -> Vec<Tok> {
        let mut diags = Diagnostics::new();
        let toks = lex(source, &mut diags);
        assert!(!diags.has_errors(), "unexpected lex errors: {diags}");
        toks.into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn lexes_statements_and_collapsed_newlines() {
        let toks = kinds("seed = 7\n\n\nworkers = 2\n");
        assert_eq!(
            toks,
            vec![
                Tok::Ident("seed".into()),
                Tok::Eq,
                Tok::Num(7.0),
                Tok::Newline,
                Tok::Ident("workers".into()),
                Tok::Eq,
                Tok::Num(2.0),
                Tok::Newline,
                Tok::Eof,
            ]
        );
    }

    #[test]
    fn dims_and_numbers_are_distinct() {
        let toks = kinds("array = [8x8, 16x16]\nglb = [128, 2.5]");
        assert!(toks.contains(&Tok::Dims(8, 8)));
        assert!(toks.contains(&Tok::Dims(16, 16)));
        assert!(toks.contains(&Tok::Num(128.0)));
        assert!(toks.contains(&Tok::Num(2.5)));
    }

    #[test]
    fn newlines_are_soft_inside_brackets_and_parens() {
        let toks = kinds("models = [\n  resnet20,\n  vgg16\n]\n");
        let newlines = toks.iter().filter(|t| matches!(t, Tok::Newline)).count();
        assert_eq!(newlines, 1, "only the statement terminator survives: {toks:?}");
        let toks = kinds("strategy = random(\n  64\n)\n");
        let newlines = toks.iter().filter(|t| matches!(t, Tok::Newline)).count();
        assert_eq!(newlines, 1);
    }

    #[test]
    fn comments_and_strings() {
        let toks = kinds("# a comment\ndb = \"out/db.json\" # trailing\n");
        assert_eq!(
            toks,
            vec![
                Tok::Ident("db".into()),
                Tok::Eq,
                Tok::Str("out/db.json".into()),
                Tok::Newline,
                Tok::Eof,
            ]
        );
    }

    #[test]
    fn backslash_before_multibyte_char_does_not_panic() {
        // Regression: the escape arm used to advance 2 bytes and land
        // mid-character, panicking on the next slice.
        let mut diags = Diagnostics::new();
        let toks = lex("db = \"a\\éb\"\n", &mut diags);
        assert!(!diags.has_errors(), "{diags}");
        assert!(toks.iter().any(|t| t.tok == Tok::Str("a\\éb".into())), "{toks:?}");
        // Backslash at end-of-line / end-of-file: unterminated, no panic.
        let mut diags = Diagnostics::new();
        let _ = lex("db = \"a\\\nseed = 7\n", &mut diags);
        assert!(diags.has_errors());
        let mut diags = Diagnostics::new();
        let _ = lex("db = \"a\\", &mut diags);
        assert!(diags.has_errors());
    }

    #[test]
    fn unterminated_string_is_reported_not_fatal() {
        let mut diags = Diagnostics::new();
        let toks = lex("db = \"oops\nseed = 7\n", &mut diags);
        assert!(diags.has_errors());
        // The rest of the file still lexes.
        assert!(toks.iter().any(|t| t.tok == Tok::Ident("seed".into())));
    }

    #[test]
    fn stray_characters_are_reported_and_skipped() {
        let mut diags = Diagnostics::new();
        let toks = lex("seed ? 7", &mut diags);
        assert_eq!(diags.error_count(), 1);
        assert!(toks.iter().any(|t| t.tok == Tok::Num(7.0)));
    }

    #[test]
    fn shard_designator_lexes_as_slash() {
        let toks = kinds("shard = 0 / 4");
        assert!(toks.contains(&Tok::Slash));
    }

    #[test]
    fn missing_trailing_newline_is_synthesized() {
        let toks = kinds("seed = 7");
        assert_eq!(toks[toks.len() - 2], Tok::Newline);
        assert_eq!(toks[toks.len() - 1], Tok::Eof);
    }
}
