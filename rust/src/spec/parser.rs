//! Recursive-descent parser for QSL.
//!
//! Consumes the [`lexer`](super::lexer) token stream into the spanned
//! [`ast`](super::ast). Parsing is *recovering*: a malformed statement
//! is reported into the shared [`Diagnostics`] batch and the parser
//! re-synchronizes at the next line or block boundary, so one typo does
//! not hide the rest of the file's problems. Everything semantic —
//! which keys exist, what values they accept — is deferred to
//! [`resolve`](super::resolve), which reports against the spans this
//! parser preserves.

use super::ast::{
    AccuracyBlock, Arg, Block, IncludeDecl, KeyValue, LayerStmt, ModelBlock, ModelStmt,
    OverrideBlock, Section, SpecFile, Spanned, StrategyDecl, Value, ValueKind,
};
use super::diag::{Diagnostics, Span};
use super::lexer::{lex, Tok, Token};
use crate::util::text::did_you_mean;

/// The top-level section keywords (for "did you mean" suggestions).
pub const SECTION_KEYWORDS: [&str; 10] = [
    "campaign", "sweep", "model_axes", "strategy", "workload", "model", "persist", "include",
    "override", "matrix",
];

/// Maximum `[`/`(` value-nesting depth. The grammar never needs more
/// than two levels; the cap turns adversarial `[[[[...` input into a
/// diagnostic instead of a stack overflow (mirroring
/// [`crate::util::json::MAX_DEPTH`]).
pub const MAX_VALUE_DEPTH: usize = 64;

/// Layer statement keywords inside `model` blocks.
pub const LAYER_KEYWORDS: [&str; 4] = ["conv", "fc", "pool", "layer"];

/// Parse QSL source into a [`SpecFile`], reporting every problem into
/// `diags`. Always returns a (possibly partial) tree; callers must
/// check [`Diagnostics::has_errors`] before trusting it.
pub fn parse(source: &str, diags: &mut Diagnostics) -> SpecFile {
    let tokens = lex(source, diags);
    let mut parser = Parser { tokens, pos: 0, depth: 0, diags };
    parser.file()
}

struct Parser<'d> {
    tokens: Vec<Token>,
    pos: usize,
    /// Current `[`/`(` nesting depth (capped at [`MAX_VALUE_DEPTH`]).
    depth: usize,
    diags: &'d mut Diagnostics,
}

impl Parser<'_> {
    fn peek(&self) -> &Token {
        &self.tokens[self.pos.min(self.tokens.len() - 1)]
    }

    fn peek2(&self) -> &Token {
        &self.tokens[(self.pos + 1).min(self.tokens.len() - 1)]
    }

    fn bump(&mut self) -> Token {
        let token = self.peek().clone();
        if self.pos < self.tokens.len() - 1 {
            self.pos += 1;
        }
        token
    }

    fn at(&self, tok: &Tok) -> bool {
        &self.peek().tok == tok
    }

    fn at_eof(&self) -> bool {
        matches!(self.peek().tok, Tok::Eof)
    }

    fn eat(&mut self, tok: &Tok) -> bool {
        if self.at(tok) {
            self.bump();
            true
        } else {
            false
        }
    }

    fn expect(&mut self, tok: Tok, context: &str) -> bool {
        if self.eat(&tok) {
            return true;
        }
        let found = self.peek().tok.describe();
        let span = self.peek().span;
        self.diags.error(span, format!("expected {} {context}, found {found}", tok.describe()));
        false
    }

    fn skip_newlines(&mut self) {
        while self.eat(&Tok::Newline) {}
    }

    /// Recover to the end of the current statement: consume through the
    /// next newline, stopping short of `}`/EOF so block closers survive.
    fn sync_stmt(&mut self) {
        loop {
            match &self.peek().tok {
                Tok::Newline => {
                    self.bump();
                    return;
                }
                Tok::RBrace | Tok::Eof => return,
                _ => {
                    self.bump();
                }
            }
        }
    }

    /// Recover past a whole `{ ... }` block (brace-balanced).
    fn sync_block(&mut self) {
        // Consume up to and including the opening brace, if present on
        // this line; otherwise just sync the statement.
        loop {
            match &self.peek().tok {
                Tok::LBrace => break,
                Tok::Newline | Tok::Eof => {
                    self.sync_stmt();
                    return;
                }
                _ => {
                    self.bump();
                }
            }
        }
        let mut depth = 0usize;
        loop {
            match self.bump().tok {
                Tok::LBrace => depth += 1,
                Tok::RBrace => {
                    depth = depth.saturating_sub(1);
                    if depth == 0 {
                        return;
                    }
                }
                Tok::Eof => return,
                _ => {}
            }
        }
    }

    fn file(&mut self) -> SpecFile {
        let mut file = SpecFile::default();
        loop {
            self.skip_newlines();
            if self.at_eof() {
                return file;
            }
            let token = self.peek().clone();
            match &token.tok {
                Tok::Ident(word) => match word.as_str() {
                    "campaign" | "sweep" | "model_axes" | "workload" | "persist" | "matrix" => {
                        let keyword = self.bump().span;
                        if let Some(block) = self.block(keyword) {
                            file.sections.push(match word.as_str() {
                                "campaign" => Section::Campaign(block),
                                "sweep" => Section::Sweep(block),
                                "model_axes" => Section::ModelAxes(block),
                                "workload" => Section::Workload(block),
                                "matrix" => Section::Matrix(block),
                                _ => Section::Persist(block),
                            });
                        }
                    }
                    "include" => {
                        let keyword = self.bump().span;
                        match self.peek().tok.clone() {
                            Tok::Str(path) => {
                                let span = self.peek().span;
                                self.bump();
                                file.sections.push(Section::Include(IncludeDecl {
                                    keyword,
                                    path: Spanned::new(path, span),
                                }));
                                self.end_stmt();
                            }
                            other => {
                                let span = self.peek().span;
                                self.diags.error_help(
                                    span,
                                    format!(
                                        "expected a quoted path after 'include', found {}",
                                        other.describe()
                                    ),
                                    "write include \"base.qsl\"",
                                );
                                self.sync_stmt();
                            }
                        }
                    }
                    "override" => {
                        let keyword = self.bump().span;
                        match self.ident("a section name after 'override'") {
                            Some(target) => {
                                if let Some(block) = self.block(keyword) {
                                    file.sections.push(Section::Override(OverrideBlock {
                                        keyword,
                                        target,
                                        block,
                                    }));
                                }
                            }
                            None => self.sync_block(),
                        }
                    }
                    "strategy" => {
                        let keyword = self.bump().span;
                        if !self.expect(Tok::Eq, "after 'strategy'") {
                            self.sync_stmt();
                            continue;
                        }
                        match self.value() {
                            Some(value) => {
                                file.sections.push(Section::Strategy(StrategyDecl {
                                    keyword,
                                    value,
                                }));
                                self.end_stmt();
                            }
                            None => self.sync_stmt(),
                        }
                    }
                    "model" => {
                        if let Some(model) = self.model_block() {
                            file.sections.push(Section::Model(model));
                        }
                    }
                    other => {
                        let help = did_you_mean(other, SECTION_KEYWORDS)
                            .map(|s| format!("did you mean '{s}'?"))
                            .unwrap_or_else(|| {
                                format!(
                                    "sections are: {}",
                                    crate::util::text::name_list(SECTION_KEYWORDS)
                                )
                            });
                        self.diags.error_help(
                            token.span,
                            format!("unknown section '{other}'"),
                            help,
                        );
                        self.bump();
                        self.sync_block();
                    }
                },
                _ => {
                    self.diags.error(
                        token.span,
                        format!(
                            "expected a section keyword, found {}",
                            token.tok.describe()
                        ),
                    );
                    self.sync_stmt();
                }
            }
        }
    }

    /// Expect end-of-statement: a newline (consumed) or a closing brace
    /// (left for the block loop).
    fn end_stmt(&mut self) {
        match &self.peek().tok {
            Tok::Newline => {
                self.bump();
            }
            Tok::RBrace | Tok::Eof => {}
            other => {
                let (span, found) = (self.peek().span, other.describe());
                self.diags
                    .error(span, format!("expected end of line after statement, found {found}"));
                self.sync_stmt();
            }
        }
    }

    fn block(&mut self, keyword: Span) -> Option<Block> {
        if !self.expect(Tok::LBrace, "to open the block") {
            self.sync_block();
            return None;
        }
        let mut entries = Vec::new();
        loop {
            self.skip_newlines();
            if self.eat(&Tok::RBrace) {
                return Some(Block { keyword, entries });
            }
            if self.at_eof() {
                self.diags.error(self.peek().span, "expected '}' to close the block");
                return Some(Block { keyword, entries });
            }
            match self.key_value() {
                Some(entry) => {
                    entries.push(entry);
                    self.end_stmt();
                }
                None => self.sync_stmt(),
            }
        }
    }

    fn ident(&mut self, context: &str) -> Option<Spanned<String>> {
        match &self.peek().tok {
            Tok::Ident(word) => {
                let spanned = Spanned::new(word.clone(), self.peek().span);
                self.bump();
                Some(spanned)
            }
            other => {
                let (span, found) = (self.peek().span, other.describe());
                self.diags.error(span, format!("expected {context}, found {found}"));
                None
            }
        }
    }

    fn key_value(&mut self) -> Option<KeyValue> {
        let key = self.ident("a key")?;
        if !self.expect(Tok::Eq, &format!("after key '{}'", key.node)) {
            return None;
        }
        let value = self.value()?;
        Some(KeyValue { key, value })
    }

    fn value(&mut self) -> Option<Value> {
        let token = self.peek().clone();
        match &token.tok {
            Tok::Num(x) => {
                self.bump();
                // `A / B` fraction (shard designators).
                if self.at(&Tok::Slash) {
                    self.bump();
                    if let Tok::Num(b) = self.peek().tok {
                        let end = self.bump().span;
                        return Some(Value {
                            kind: ValueKind::Fraction(*x, b),
                            span: token.span.join(end),
                        });
                    }
                    let (span, found) = (self.peek().span, self.peek().tok.describe());
                    self.diags
                        .error(span, format!("expected a number after '/', found {found}"));
                    return None;
                }
                Some(Value { kind: ValueKind::Num(*x), span: token.span })
            }
            Tok::Dims(r, c) => {
                self.bump();
                Some(Value { kind: ValueKind::Dims(*r, *c), span: token.span })
            }
            Tok::Str(text) => {
                self.bump();
                Some(Value { kind: ValueKind::Str(text.clone()), span: token.span })
            }
            Tok::Ident(word) => {
                let name = Spanned::new(word.clone(), token.span);
                self.bump();
                if self.at(&Tok::LParen) {
                    return self.nested(|parser| parser.call(name));
                }
                Some(Value { kind: ValueKind::Word(name.node), span: token.span })
            }
            Tok::LBracket => self.nested(Self::list),
            other => {
                self.diags.error(
                    token.span,
                    format!("expected a value, found {}", other.describe()),
                );
                None
            }
        }
    }

    /// Run a nested-value parse (`[...]` / `(...)`) under the depth cap.
    fn nested(&mut self, parse: impl FnOnce(&mut Self) -> Option<Value>) -> Option<Value> {
        if self.depth >= MAX_VALUE_DEPTH {
            let span = self.peek().span;
            self.diags.error(span, "value nesting too deep");
            return None;
        }
        self.depth += 1;
        let result = parse(self);
        self.depth -= 1;
        result
    }

    fn call(&mut self, name: Spanned<String>) -> Option<Value> {
        let open = self.bump().span; // consume '('
        let mut args = Vec::new();
        loop {
            if let Tok::RParen = self.peek().tok {
                let close = self.bump().span;
                return Some(Value {
                    kind: ValueKind::Call { name: name.clone(), args },
                    span: name.span.join(close),
                });
            }
            if self.at_eof() {
                self.diags.error(open, "unclosed '(' in call");
                return None;
            }
            // Named argument: `ident = value`.
            let arg_name = match (&self.peek().tok, &self.peek2().tok) {
                (Tok::Ident(word), Tok::Eq) => {
                    let spanned = Spanned::new(word.clone(), self.peek().span);
                    self.bump();
                    self.bump();
                    Some(spanned)
                }
                _ => None,
            };
            let value = self.value()?;
            args.push(Arg { name: arg_name, value });
            if !self.eat(&Tok::Comma) && !self.at(&Tok::RParen) {
                let (span, found) = (self.peek().span, self.peek().tok.describe());
                self.diags
                    .error(span, format!("expected ',' or ')' in call arguments, found {found}"));
                return None;
            }
        }
    }

    fn list(&mut self) -> Option<Value> {
        let open = self.bump().span; // consume '['
        let mut items = Vec::new();
        loop {
            if let Tok::RBracket = self.peek().tok {
                let close = self.bump().span;
                return Some(Value { kind: ValueKind::List(items), span: open.join(close) });
            }
            if self.at_eof() {
                self.diags.error(open, "unclosed '[' in list");
                return None;
            }
            let item = self.value()?;
            items.push(item);
            if !self.eat(&Tok::Comma) && !self.at(&Tok::RBracket) {
                let (span, found) = (self.peek().span, self.peek().tok.describe());
                self.diags
                    .error(span, format!("expected ',' or ']' in list, found {found}"));
                return None;
            }
        }
    }

    fn model_block(&mut self) -> Option<ModelBlock> {
        let keyword = self.bump().span; // consume 'model'
        let name = match self.ident("a model name after 'model'") {
            Some(name) => name,
            None => {
                self.sync_block();
                return None;
            }
        };
        let like = if let Tok::Ident(word) = &self.peek().tok {
            if word == "like" {
                self.bump();
                match self.ident("a zoo model name after 'like'") {
                    Some(target) => Some(target),
                    None => {
                        self.sync_block();
                        return None;
                    }
                }
            } else {
                let (span, word) = (self.peek().span, word.clone());
                self.diags.error_help(
                    span,
                    format!("unexpected '{word}' after the model name"),
                    "write 'model NAME { ... }' or 'model NAME like ZOO { ... }'",
                );
                self.sync_block();
                return None;
            }
        } else {
            None
        };
        if !self.expect(Tok::LBrace, "to open the model block") {
            self.sync_block();
            return None;
        }
        let mut stmts = Vec::new();
        loop {
            self.skip_newlines();
            if self.eat(&Tok::RBrace) {
                return Some(ModelBlock { keyword, name, like, stmts });
            }
            if self.at_eof() {
                self.diags.error(self.peek().span, "expected '}' to close the model block");
                return Some(ModelBlock { keyword, name, like, stmts });
            }
            match self.model_stmt() {
                Some(stmt) => {
                    stmts.push(stmt);
                    self.end_stmt();
                }
                None => self.sync_stmt(),
            }
        }
    }

    fn model_stmt(&mut self) -> Option<ModelStmt> {
        // `accuracy { ... }` — user-declared per-PE-type accuracies.
        if let (Tok::Ident(word), Tok::LBrace) = (&self.peek().tok, &self.peek2().tok) {
            if word == "accuracy" {
                return self.accuracy_block().map(ModelStmt::Accuracy);
            }
        }
        // A layer statement is `KIND NAME { ... }`; anything with `=`
        // after the first word is a plain key/value.
        if let (Tok::Ident(word), Tok::Ident(_)) = (&self.peek().tok, &self.peek2().tok) {
            if LAYER_KEYWORDS.contains(&word.as_str()) {
                return self.layer_stmt().map(ModelStmt::Layer);
            }
            let (span, word) = (self.peek().span, word.clone());
            let help = did_you_mean(&word, LAYER_KEYWORDS)
                .map(|s| format!("did you mean '{s}'?"))
                .unwrap_or_else(|| "layer statements are conv/fc/pool/layer NAME { ... }".into());
            self.diags
                .error_help(span, format!("unknown layer kind '{word}'"), help);
            return None;
        }
        self.key_value().map(ModelStmt::KeyValue)
    }

    fn accuracy_block(&mut self) -> Option<AccuracyBlock> {
        let keyword = self.bump().span; // consume 'accuracy'
        if !self.expect(Tok::LBrace, "to open the accuracy block") {
            return None;
        }
        let mut entries = Vec::new();
        loop {
            self.skip_newlines();
            if let Tok::RBrace = self.peek().tok {
                self.bump();
                return Some(AccuracyBlock { keyword, entries });
            }
            if self.at_eof() {
                self.diags.error(self.peek().span, "expected '}' to close the accuracy block");
                return Some(AccuracyBlock { keyword, entries });
            }
            let entry = self.key_value()?;
            entries.push(entry);
            // Entries separate with ',' or a newline (the loop head
            // consumes newline runs); '}' closes the block.
            let newline_separated = matches!(self.peek().tok, Tok::Newline);
            if !self.eat(&Tok::Comma)
                && !newline_separated
                && !matches!(self.peek().tok, Tok::RBrace)
            {
                let (span, found) = (self.peek().span, self.peek().tok.describe());
                self.diags.error(
                    span,
                    format!("expected ',' or '}}' in accuracy entries, found {found}"),
                );
                return None;
            }
        }
    }

    fn layer_stmt(&mut self) -> Option<LayerStmt> {
        let kind_token = self.bump();
        let kind = match kind_token.tok {
            Tok::Ident(word) => Spanned::new(word, kind_token.span),
            _ => unreachable!("layer_stmt is only entered on an identifier"),
        };
        let name = self.ident("a layer name")?;
        if !self.expect(Tok::LBrace, "to open the layer fields") {
            return None;
        }
        let mut fields = Vec::new();
        loop {
            self.skip_newlines();
            if let Tok::RBrace = self.peek().tok {
                let close = self.bump().span;
                return Some(LayerStmt {
                    span: kind.span.join(close),
                    kind,
                    name,
                    fields,
                });
            }
            if self.at_eof() {
                self.diags.error(self.peek().span, "expected '}' to close the layer fields");
                let end = self.peek().span;
                return Some(LayerStmt { span: kind.span.join(end), kind, name, fields });
            }
            let field = self.key_value()?;
            fields.push(field);
            self.skip_newlines();
            if !self.eat(&Tok::Comma) && !matches!(self.peek().tok, Tok::RBrace) {
                let (span, found) = (self.peek().span, self.peek().tok.describe());
                self.diags
                    .error(span, format!("expected ',' or '}}' in layer fields, found {found}"));
                return None;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_ok(source: &str) -> SpecFile {
        let mut diags = Diagnostics::new();
        let file = parse(source, &mut diags);
        assert!(!diags.has_errors(), "unexpected errors:\n{}", diags.render(source, "t.qsl"));
        file
    }

    #[test]
    fn parses_all_section_kinds() {
        let file = parse_ok(
            "campaign {\n  seed = 7\n  shard = 0 / 2\n}\n\
             sweep {\n  pe_type = [int16, lightpe1]\n  array = [8x8]\n}\n\
             strategy = random(64, seed = 11)\n\
             workload {\n  dataset = cifar10\n  models = [resnet20]\n}\n\
             model tiny {\n  conv c1 { in = 32, channels = 3, out = 16, kernel = 3 }\n  fc head { in = 4096, out = 10 }\n}\n\
             persist {\n  db = \"out/db.json\"\n}\n",
        );
        assert_eq!(file.sections.len(), 6);
        assert!(matches!(file.sections[0], Section::Campaign(_)));
        assert!(matches!(file.sections[2], Section::Strategy(_)));
        match &file.sections[4] {
            Section::Model(model) => {
                assert_eq!(model.name.node, "tiny");
                assert!(model.like.is_none());
                assert_eq!(model.stmts.len(), 2);
            }
            other => panic!("expected a model, got {other:?}"),
        }
    }

    #[test]
    fn parses_like_models_with_overrides() {
        let file = parse_ok(
            "model wide like resnet20 {\n  dataset = cifar100\n  layer fc { out = 100 }\n}\n",
        );
        match &file.sections[0] {
            Section::Model(model) => {
                assert_eq!(model.like.as_ref().unwrap().node, "resnet20");
                assert!(matches!(model.stmts[0], ModelStmt::KeyValue(_)));
                match &model.stmts[1] {
                    ModelStmt::Layer(layer) => {
                        assert_eq!(layer.kind.node, "layer");
                        assert_eq!(layer.name.node, "fc");
                        assert_eq!(layer.fields.len(), 1);
                    }
                    other => panic!("expected a layer override, got {other:?}"),
                }
            }
            other => panic!("expected a model, got {other:?}"),
        }
    }

    #[test]
    fn parses_model_axes_section() {
        let file = parse_ok("model_axes {\n  width = [0.25, 0.5, 1]\n  depth = [1, 2]\n}\n");
        match &file.sections[0] {
            Section::ModelAxes(block) => {
                assert_eq!(block.entries.len(), 2);
                assert_eq!(block.entries[0].key.node, "width");
                match &block.entries[0].value.kind {
                    ValueKind::List(items) => assert_eq!(items.len(), 3),
                    other => panic!("expected a list, got {other:?}"),
                }
            }
            other => panic!("expected model_axes, got {other:?}"),
        }
    }

    #[test]
    fn parses_accuracy_blocks_in_models() {
        let file = parse_ok(
            "model tiny {\n  accuracy { int16 = 91.2, lightpe1 = 90.1 }\n  \
             fc head { in = 64, out = 10 }\n}\n",
        );
        match &file.sections[0] {
            Section::Model(model) => {
                assert_eq!(model.stmts.len(), 2);
                match &model.stmts[0] {
                    ModelStmt::Accuracy(block) => {
                        assert_eq!(block.entries.len(), 2);
                        assert_eq!(block.entries[0].key.node, "int16");
                        assert_eq!(block.entries[1].key.node, "lightpe1");
                    }
                    other => panic!("expected an accuracy block, got {other:?}"),
                }
            }
            other => panic!("expected a model, got {other:?}"),
        }
        // Newline-separated entries parse too.
        let file = parse_ok(
            "model tiny {\n  accuracy {\n    int16 = 91.2\n    fp32 = 92.0\n  }\n  \
             fc head { in = 64, out = 10 }\n}\n",
        );
        assert!(matches!(&file.sections[0], Section::Model(_)));
    }

    #[test]
    fn multiline_lists_parse() {
        let file = parse_ok("sweep {\n  glb_kib = [\n    64,\n    128\n  ]\n}\n");
        match &file.sections[0] {
            Section::Sweep(block) => match &block.entries[0].value.kind {
                ValueKind::List(items) => assert_eq!(items.len(), 2),
                other => panic!("expected a list, got {other:?}"),
            },
            other => panic!("expected sweep, got {other:?}"),
        }
    }

    #[test]
    fn reports_multiple_errors_in_one_pass() {
        let source = "campaing {\n  seed = 7\n}\n\
                      sweep {\n  pe_type = \n}\n\
                      strategy = \n";
        let mut diags = Diagnostics::new();
        let _ = parse(source, &mut diags);
        assert!(diags.error_count() >= 3, "wanted >=3 errors, got:\n{diags}");
        let rendered = diags.render(source, "bad.qsl");
        assert!(rendered.contains("did you mean 'campaign'?"), "{rendered}");
    }

    #[test]
    fn recovers_within_a_block() {
        // One bad statement must not eat the good one after it.
        let source = "campaign {\n  seed 7\n  workers = 2\n}\n";
        let mut diags = Diagnostics::new();
        let file = parse(source, &mut diags);
        assert!(diags.has_errors());
        match &file.sections[0] {
            Section::Campaign(block) => {
                assert_eq!(block.entries.len(), 1);
                assert_eq!(block.entries[0].key.node, "workers");
            }
            other => panic!("expected campaign, got {other:?}"),
        }
    }

    #[test]
    fn pathological_nesting_is_an_error_not_a_stack_overflow() {
        for depth in [MAX_VALUE_DEPTH + 1, 10_000] {
            let source = format!("sweep {{\n  glb_kib = {}64\n}}\n", "[".repeat(depth));
            let mut diags = Diagnostics::new();
            let _ = parse(&source, &mut diags);
            assert!(diags.has_errors(), "depth {depth} must error");
        }
        // Shallow nesting (the grammar's real shapes) still parses.
        let _ = parse_ok("sweep {\n  spad = [spad(1, 2, 3)]\n}\n");
    }

    #[test]
    fn unknown_section_skips_its_block() {
        let source = "swep {\n  pe_type = [int16]\n}\npersist {\n  db = \"x\"\n}\n";
        let mut diags = Diagnostics::new();
        let file = parse(source, &mut diags);
        assert_eq!(diags.error_count(), 1);
        assert_eq!(file.sections.len(), 1);
        assert!(matches!(file.sections[0], Section::Persist(_)));
    }
}
