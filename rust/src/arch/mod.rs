//! Accelerator architecture description (the paper's Figure 1 inputs).
//!
//! An [`AcceleratorConfig`] fixes every hardware knob the paper sweeps:
//! PE type / bit precision, PE array dimensions, per-PE scratchpad sizes,
//! global buffer size, DRAM bandwidth, and target clock. [`SweepSpec`]
//! enumerates the hardware cross-product (§III-C), and [`DesignSpace`]
//! crosses it with [`ModelAxes`] (width/depth multipliers) into the
//! joint hardware × model space of QUIDAM-style co-exploration.

pub mod sweep;

pub use sweep::{DesignSpace, JointPoint, ModelAxes, ModelVariant, SweepIter, SweepSpec};

use crate::error::{Error, Result};
use crate::quant::PeType;
use crate::util::json::{num, obj, s, Json};

/// Per-PE scratchpad configuration, in *entries* (words of the natural
/// width: ifmap entries are activation-wide, filter entries weight-wide,
/// psum entries accumulator-wide). Defaults follow Eyeriss's RS PE.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ScratchpadCfg {
    /// Input-feature-map entries (activation-wide words).
    pub ifmap_entries: usize,
    /// Filter-weight entries (weight-wide words).
    pub filter_entries: usize,
    /// Partial-sum entries (accumulator-wide words).
    pub psum_entries: usize,
}

impl Default for ScratchpadCfg {
    fn default() -> Self {
        // Eyeriss-like RS PE: 12-entry ifmap spad, 224-entry filter spad,
        // 24-entry psum spad.
        Self { ifmap_entries: 12, filter_entries: 224, psum_entries: 24 }
    }
}

impl ScratchpadCfg {
    /// Total scratchpad storage in bits for a given PE type.
    pub fn total_bits(&self, pe: PeType) -> usize {
        self.ifmap_entries * pe.act_bits() as usize
            + self.filter_entries * pe.weight_bits() as usize
            + self.psum_entries * pe.psum_bits() as usize
    }
}

/// A complete accelerator design point.
#[derive(Debug, Clone, PartialEq)]
pub struct AcceleratorConfig {
    /// Processing element type (fixes all datapath bit widths).
    pub pe: PeType,
    /// PE array rows.
    pub rows: usize,
    /// PE array columns.
    pub cols: usize,
    /// Per-PE scratchpad sizes.
    pub spad: ScratchpadCfg,
    /// Global buffer capacity in KiB.
    pub glb_kib: usize,
    /// Off-chip DRAM bandwidth in GB/s.
    pub dram_bw_gbps: f64,
    /// Target clock in GHz (the synthesis engine reports the achievable
    /// clock; the design runs at `min(target, achievable)`).
    pub clock_ghz: f64,
}

impl Default for AcceleratorConfig {
    fn default() -> Self {
        Self {
            pe: PeType::Int16,
            rows: 16,
            cols: 16,
            spad: ScratchpadCfg::default(),
            glb_kib: 128,
            dram_bw_gbps: 8.0,
            clock_ghz: 2.0,
        }
    }
}

impl AcceleratorConfig {
    /// Total number of PEs.
    pub fn num_pes(&self) -> usize {
        self.rows * self.cols
    }

    /// Global buffer capacity in bytes.
    pub fn glb_bytes(&self) -> usize {
        self.glb_kib * 1024
    }

    /// Peak MACs per cycle (one MAC per PE per cycle under row-stationary).
    pub fn peak_macs_per_cycle(&self) -> usize {
        self.num_pes()
    }

    /// Short identifier used in logs, CSVs, and artifact names.
    pub fn id(&self) -> String {
        format!(
            "{}_r{}c{}_g{}k_i{}f{}p{}_bw{}_ck{}",
            self.pe.name().replace('-', ""),
            self.rows,
            self.cols,
            self.glb_kib,
            self.spad.ifmap_entries,
            self.spad.filter_entries,
            self.spad.psum_entries,
            self.dram_bw_gbps as u64,
            (self.clock_ghz * 10.0) as u64
        )
    }

    /// Validate structural invariants; returns [`Error::InvalidConfig`]
    /// describing the first violation, if any.
    pub fn validate(&self) -> Result<()> {
        let invalid = |msg: &str| Err(Error::InvalidConfig(msg.into()));
        if self.rows == 0 || self.cols == 0 {
            return invalid("PE array dimensions must be positive");
        }
        if self.rows > 256 || self.cols > 256 {
            return invalid("PE array dimension exceeds supported maximum (256)");
        }
        if self.glb_kib == 0 {
            return invalid("global buffer must be non-empty");
        }
        if self.spad.ifmap_entries == 0
            || self.spad.filter_entries == 0
            || self.spad.psum_entries == 0
        {
            return invalid("scratchpads must be non-empty");
        }
        if self.dram_bw_gbps.is_nan() || self.dram_bw_gbps <= 0.0 {
            return invalid("DRAM bandwidth must be positive");
        }
        if !(self.clock_ghz > 0.0 && self.clock_ghz <= 5.0) {
            return invalid("clock target must be in (0, 5] GHz");
        }
        Ok(())
    }

    /// Serialize to JSON (config dumps and DSE result records).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("pe", s(self.pe.name())),
            ("rows", num(self.rows as f64)),
            ("cols", num(self.cols as f64)),
            ("ifmap_spad", num(self.spad.ifmap_entries as f64)),
            ("filter_spad", num(self.spad.filter_entries as f64)),
            ("psum_spad", num(self.spad.psum_entries as f64)),
            ("glb_kib", num(self.glb_kib as f64)),
            ("dram_bw_gbps", num(self.dram_bw_gbps)),
            ("clock_ghz", num(self.clock_ghz)),
        ])
    }

    /// Deserialize from JSON produced by [`Self::to_json`].
    pub fn from_json(json: &Json) -> Result<Self> {
        let get_num = |key: &str| -> Result<f64> {
            json.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| Error::ParseError(format!("missing numeric field '{key}'")))
        };
        let pe_name = json
            .get("pe")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::ParseError("missing field 'pe'".into()))?;
        let pe = PeType::parse(pe_name)
            .ok_or_else(|| Error::ParseError(format!("unknown PE type '{pe_name}'")))?;
        let cfg = Self {
            pe,
            rows: get_num("rows")? as usize,
            cols: get_num("cols")? as usize,
            spad: ScratchpadCfg {
                ifmap_entries: get_num("ifmap_spad")? as usize,
                filter_entries: get_num("filter_spad")? as usize,
                psum_entries: get_num("psum_spad")? as usize,
            },
            glb_kib: get_num("glb_kib")? as usize,
            dram_bw_gbps: get_num("dram_bw_gbps")?,
            clock_ghz: get_num("clock_ghz")?,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        assert!(AcceleratorConfig::default().validate().is_ok());
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut cfg = AcceleratorConfig::default();
        cfg.rows = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = AcceleratorConfig::default();
        cfg.glb_kib = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = AcceleratorConfig::default();
        cfg.dram_bw_gbps = -1.0;
        assert!(cfg.validate().is_err());
        let mut cfg = AcceleratorConfig::default();
        cfg.dram_bw_gbps = f64::NAN;
        assert!(cfg.validate().is_err(), "NaN bandwidth must be rejected");
        let mut cfg = AcceleratorConfig::default();
        cfg.clock_ghz = 9.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn spad_bits_scale_with_precision() {
        let spad = ScratchpadCfg::default();
        let int16 = spad.total_bits(PeType::Int16);
        let light1 = spad.total_bits(PeType::LightPe1);
        let fp32 = spad.total_bits(PeType::Fp32);
        assert!(fp32 > int16, "FP32 spads must be biggest");
        assert!(int16 > light1, "LightPE-1 spads must be smallest");
    }

    #[test]
    fn json_roundtrip() {
        let cfg = AcceleratorConfig {
            pe: PeType::LightPe2,
            rows: 12,
            cols: 14,
            spad: ScratchpadCfg { ifmap_entries: 24, filter_entries: 448, psum_entries: 32 },
            glb_kib: 256,
            dram_bw_gbps: 16.0,
            clock_ghz: 1.2,
        };
        let parsed = AcceleratorConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(parsed, cfg);
    }

    #[test]
    fn from_json_rejects_missing_fields() {
        let json = Json::parse(r#"{"pe": "INT16"}"#).unwrap();
        assert!(AcceleratorConfig::from_json(&json).is_err());
    }

    #[test]
    fn id_distinguishes_configs() {
        let a = AcceleratorConfig::default();
        let mut b = a.clone();
        b.rows = 32;
        assert_ne!(a.id(), b.id());
    }
}
