//! Design-space enumeration (§III-C), generalized to *joint*
//! hardware × model spaces (the QUIDAM co-exploration direction).
//!
//! Two layers of typed axes:
//!
//! * [`SweepSpec`] — the paper's six **hardware** axes; iteration yields
//!   the full cross-product as concrete [`AcceleratorConfig`]s. The
//!   space is *lazily* enumerated: [`SweepSpec::iter`] decodes design
//!   points from a mixed-radix index in O(1) memory, [`SweepSpec::get`]
//!   addresses any point directly, and [`SweepSpec::shard_iter`]
//!   exposes a round-robin shard view without materializing the space.
//!   The default space mirrors the paper's: 4 PE types × array sizes ×
//!   global buffer sizes × scratchpad variants.
//! * [`ModelAxes`] — **model-hyperparameter** axes: width multipliers ×
//!   depth multipliers applied to every base workload model
//!   (lowered to concrete models by [`crate::dnn::scale_model`]).
//!
//! A [`DesignSpace`] is the cross-product of both layers. Every joint
//! point has a mixed-radix index (model variant outermost, hardware
//! innermost), so the same O(1) `get`/`iter`/`shard_iter` addressing —
//! and everything built on it: strategy selection, sharding, checkpoint
//! journals, replay cursors — works over the joint space unchanged.
//! A `DesignSpace` with trivial model axes (`width = [1.0]`,
//! `depth = [1]`) is indistinguishable from its bare [`SweepSpec`]:
//! same indices, same JSON, same [`DesignSpace::fingerprint`] — which
//! is what keeps pre-joint campaign artifacts byte-identical and
//! journals interchangeable.

use super::{AcceleratorConfig, ScratchpadCfg};
use crate::error::{Error, Result};
use crate::quant::PeType;
use crate::util::json::{num, obj, s, Json};

/// Candidate values per design-space axis.
///
/// The cross-product is enumerated lazily: [`Self::get`] decodes any
/// point from its mixed-radix index in O(1), so iteration, random
/// access, and shard views never materialize the space.
///
/// ```
/// use qadam::arch::SweepSpec;
///
/// let spec = SweepSpec::tiny();
/// assert_eq!(spec.len(), 4); // 2 PE types × 2 array sizes
/// // Random access agrees with iteration order.
/// let third = spec.get(2).unwrap();
/// assert_eq!(spec.iter().nth(2).unwrap(), third);
/// // Shards partition the space without materializing it.
/// let counts: usize = (0..3).map(|s| spec.shard_iter(s, 3).len()).sum();
/// assert_eq!(counts, spec.len());
/// ```
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Candidate PE types.
    pub pe_types: Vec<PeType>,
    /// (rows, cols) pairs.
    pub array_dims: Vec<(usize, usize)>,
    /// Candidate global-buffer capacities (KiB).
    pub glb_kib: Vec<usize>,
    /// Candidate per-PE scratchpad configurations.
    pub spads: Vec<ScratchpadCfg>,
    /// Candidate DRAM bandwidths (GB/s).
    pub dram_bw_gbps: Vec<f64>,
    /// Candidate clock targets (GHz).
    pub clock_ghz: Vec<f64>,
}

impl Default for SweepSpec {
    fn default() -> Self {
        Self {
            pe_types: PeType::ALL.to_vec(),
            array_dims: vec![(8, 8), (12, 14), (16, 16), (24, 24), (32, 32)],
            glb_kib: vec![64, 128, 256, 512],
            spads: vec![
                ScratchpadCfg { ifmap_entries: 6, filter_entries: 28, psum_entries: 8 },
                ScratchpadCfg { ifmap_entries: 12, filter_entries: 112, psum_entries: 16 },
                ScratchpadCfg { ifmap_entries: 12, filter_entries: 224, psum_entries: 24 },
                ScratchpadCfg { ifmap_entries: 24, filter_entries: 448, psum_entries: 32 },
            ],
            dram_bw_gbps: vec![8.0, 16.0, 32.0],
            clock_ghz: vec![2.0],
        }
    }
}

impl SweepSpec {
    /// A small spec for fast tests (2 PE types × 2 arrays × 1 of the rest).
    pub fn tiny() -> Self {
        Self {
            pe_types: vec![PeType::Int16, PeType::LightPe1],
            array_dims: vec![(8, 8), (16, 16)],
            glb_kib: vec![128],
            spads: vec![ScratchpadCfg::default()],
            dram_bw_gbps: vec![8.0],
            clock_ghz: vec![2.0],
        }
    }

    /// Restrict to a single PE type (used by per-type model fitting).
    pub fn for_pe(mut self, pe: PeType) -> Self {
        self.pe_types = vec![pe];
        self
    }

    /// Number of design points in the cross-product.
    pub fn len(&self) -> usize {
        self.pe_types.len()
            * self.array_dims.len()
            * self.glb_kib.len()
            * self.spads.len()
            * self.dram_bw_gbps.len()
            * self.clock_ghz.len()
    }

    /// Whether the spec is degenerate (any empty axis).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The QSL-facing name of the first empty axis, if any — so
    /// degenerate-space errors can say *which* axis has no candidates
    /// instead of a generic "empty space" message.
    pub fn empty_axis(&self) -> Option<&'static str> {
        [
            ("pe_type", self.pe_types.is_empty()),
            ("array", self.array_dims.is_empty()),
            ("glb_kib", self.glb_kib.is_empty()),
            ("spad", self.spads.is_empty()),
            ("dram_gbps", self.dram_bw_gbps.is_empty()),
            ("clock_ghz", self.clock_ghz.is_empty()),
        ]
        .into_iter()
        .find_map(|(name, empty)| empty.then_some(name))
    }

    /// Decode the `index`-th design point of the cross-product without
    /// materializing anything. Point order matches nested loops with
    /// `pe_types` outermost and `clock_ghz` innermost; `None` when
    /// `index >= self.len()`.
    pub fn get(&self, index: usize) -> Option<AcceleratorConfig> {
        if index >= self.len() {
            return None;
        }
        // Mixed-radix decode, least-significant (innermost) axis first.
        let mut rest = index;
        let mut digit = |len: usize| {
            let d = rest % len;
            rest /= len;
            d
        };
        let clock_ghz = self.clock_ghz[digit(self.clock_ghz.len())];
        let dram_bw_gbps = self.dram_bw_gbps[digit(self.dram_bw_gbps.len())];
        let spad = self.spads[digit(self.spads.len())];
        let glb_kib = self.glb_kib[digit(self.glb_kib.len())];
        let (rows, cols) = self.array_dims[digit(self.array_dims.len())];
        let pe = self.pe_types[rest];
        Some(AcceleratorConfig { pe, rows, cols, spad, glb_kib, dram_bw_gbps, clock_ghz })
    }

    /// Lazy iterator over the cross-product (O(1) memory; `nth` is O(1)).
    pub fn iter(&self) -> SweepIter<'_> {
        SweepIter { spec: self, next: 0, end: self.len() }
    }

    /// Lazy round-robin shard view: the design points whose index `i`
    /// satisfies `i % num_shards == shard`, in index order — the same
    /// points `iter().skip(shard).step_by(num_shards)` would yield, but
    /// index-addressed so it stays O(1) per point.
    ///
    /// # Panics
    /// If `num_shards == 0` or `shard >= num_shards`.
    // `shard + pos * num_shards < len` by the `count` arithmetic below.
    #[allow(clippy::expect_used)]
    pub fn shard_iter(
        &self,
        shard: usize,
        num_shards: usize,
    ) -> impl ExactSizeIterator<Item = AcceleratorConfig> + '_ {
        assert!(
            num_shards > 0 && shard < num_shards,
            "shard {shard} out of range for {num_shards} shards"
        );
        let len = self.len();
        let count = if shard < len { (len - shard).div_ceil(num_shards) } else { 0 };
        (0..count).map(move |pos| {
            self.get(shard + pos * num_shards).expect("shard index within cross-product")
        })
    }

    /// Materialize the full cross-product. Prefer [`Self::iter`] on hot
    /// paths — this allocates one `Vec` entry per design point.
    pub fn enumerate(&self) -> Vec<AcceleratorConfig> {
        self.iter().collect()
    }

    /// Serialize to JSON (the `--sweep <file>` config format).
    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "pe_types",
                Json::Arr(self.pe_types.iter().map(|p| s(p.name())).collect()),
            ),
            (
                "array_dims",
                Json::Arr(
                    self.array_dims
                        .iter()
                        .map(|&(r, c)| Json::Arr(vec![num(r as f64), num(c as f64)]))
                        .collect(),
                ),
            ),
            (
                "glb_kib",
                Json::Arr(self.glb_kib.iter().map(|&g| num(g as f64)).collect()),
            ),
            (
                "spads",
                Json::Arr(
                    self.spads
                        .iter()
                        .map(|sp| {
                            obj(vec![
                                ("ifmap", num(sp.ifmap_entries as f64)),
                                ("filter", num(sp.filter_entries as f64)),
                                ("psum", num(sp.psum_entries as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "dram_bw_gbps",
                Json::Arr(self.dram_bw_gbps.iter().map(|&b| num(b)).collect()),
            ),
            (
                "clock_ghz",
                Json::Arr(self.clock_ghz.iter().map(|&c| num(c)).collect()),
            ),
        ])
    }

    /// Deserialize from the JSON produced by [`Self::to_json`]. Missing
    /// axes fall back to the defaults, so config files can override only
    /// the axes they care about.
    pub fn from_json(json: &Json) -> Result<Self> {
        let mut spec = SweepSpec::default();
        if let Some(items) = json.get("pe_types").and_then(Json::as_arr) {
            spec.pe_types = items
                .iter()
                .map(|v| {
                    v.as_str()
                        .and_then(PeType::parse)
                        .ok_or_else(|| Error::ParseError(format!("bad pe type {v:?}")))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(items) = json.get("array_dims").and_then(Json::as_arr) {
            spec.array_dims = items
                .iter()
                .map(|v| {
                    let pair = v.as_arr().ok_or_else(|| {
                        Error::ParseError("array_dims entries must be [rows, cols]".into())
                    })?;
                    match (pair.first().and_then(Json::as_i64), pair.get(1).and_then(Json::as_i64))
                    {
                        (Some(r), Some(c)) if r > 0 && c > 0 => Ok((r as usize, c as usize)),
                        _ => Err(Error::ParseError(
                            "array_dims entries must be positive integers".into(),
                        )),
                    }
                })
                .collect::<Result<_>>()?;
        }
        if let Some(items) = json.get("glb_kib").and_then(Json::as_arr) {
            spec.glb_kib = items
                .iter()
                .map(|v| {
                    v.as_i64()
                        .map(|g| g as usize)
                        .ok_or_else(|| Error::ParseError("bad glb_kib".into()))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(items) = json.get("spads").and_then(Json::as_arr) {
            spec.spads = items
                .iter()
                .map(|v| {
                    let field = |k: &str| {
                        v.get(k)
                            .and_then(Json::as_i64)
                            .map(|x| x as usize)
                            .ok_or_else(|| Error::ParseError(format!("spad entry missing '{k}'")))
                    };
                    Ok(ScratchpadCfg {
                        ifmap_entries: field("ifmap")?,
                        filter_entries: field("filter")?,
                        psum_entries: field("psum")?,
                    })
                })
                .collect::<Result<_>>()?;
        }
        if let Some(items) = json.get("dram_bw_gbps").and_then(Json::as_arr) {
            spec.dram_bw_gbps = items
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| Error::ParseError("bad dram_bw_gbps".into())))
                .collect::<Result<_>>()?;
        }
        if let Some(items) = json.get("clock_ghz").and_then(Json::as_arr) {
            spec.clock_ghz = items
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| Error::ParseError("bad clock_ghz".into())))
                .collect::<Result<_>>()?;
        }
        if let Some(axis) = spec.empty_axis() {
            return Err(Error::InvalidConfig(format!(
                "sweep axis '{axis}' lists no candidate values: the design space is empty"
            )));
        }
        Ok(spec)
    }

    /// Stable 64-bit fingerprint of the design space: FNV-1a over the
    /// canonical JSON rendering (sorted keys, shortest round-trip
    /// numbers), so it survives process restarts and platform changes.
    /// Checkpoint journals embed it to reject resumes against a different
    /// space (`explore::persist`).
    pub fn fingerprint(&self) -> u64 {
        crate::util::fnv1a_64(self.to_json().to_string_canonical().as_bytes())
    }

    /// Load a sweep from a JSON file.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let json = Json::parse(&text)?;
        Self::from_json(&json)
    }
}

/// Lazy iterator over a [`SweepSpec`] cross-product (see [`SweepSpec::iter`]).
#[derive(Debug, Clone)]
pub struct SweepIter<'a> {
    spec: &'a SweepSpec,
    next: usize,
    end: usize,
}

impl Iterator for SweepIter<'_> {
    type Item = AcceleratorConfig;

    fn next(&mut self) -> Option<AcceleratorConfig> {
        if self.next >= self.end {
            return None;
        }
        let config = self.spec.get(self.next);
        self.next += 1;
        config
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let remaining = self.end - self.next;
        (remaining, Some(remaining))
    }

    fn nth(&mut self, n: usize) -> Option<AcceleratorConfig> {
        // Clamp so an overshooting skip cannot push `next` past `end`
        // (which would underflow `size_hint`).
        self.next = self.next.saturating_add(n).min(self.end);
        self.next()
    }
}

impl ExactSizeIterator for SweepIter<'_> {}

impl DoubleEndedIterator for SweepIter<'_> {
    fn next_back(&mut self) -> Option<AcceleratorConfig> {
        if self.next >= self.end {
            return None;
        }
        self.end -= 1;
        self.spec.get(self.end)
    }
}

impl<'a> IntoIterator for &'a SweepSpec {
    type Item = AcceleratorConfig;
    type IntoIter = SweepIter<'a>;

    fn into_iter(self) -> SweepIter<'a> {
        self.iter()
    }
}

// ---------------------------------------------------------------------------
// Model axes and the joint design space.

/// Model-hyperparameter sweep axes: width multipliers × depth
/// multipliers applied to every base workload model (the QUIDAM-style
/// co-exploration knobs). The default — `width = [1.0]`, `depth = [1]`
/// — is the *trivial* axes: exactly one variant, the base model itself,
/// and a [`DesignSpace`] carrying it behaves identically to its bare
/// [`SweepSpec`].
#[derive(Debug, Clone, PartialEq)]
pub struct ModelAxes {
    /// Candidate channel-width multipliers (each > 0; `1.0` = base).
    pub width_mults: Vec<f64>,
    /// Candidate depth multipliers (each ≥ 1; `1` = base).
    pub depth_mults: Vec<usize>,
}

impl Default for ModelAxes {
    fn default() -> Self {
        Self { width_mults: vec![1.0], depth_mults: vec![1] }
    }
}

impl ModelAxes {
    /// Whether these are the default axes (exactly the base model).
    pub fn is_trivial(&self) -> bool {
        self.width_mults == [1.0] && self.depth_mults == [1]
    }

    /// Number of model variants in the cross-product.
    pub fn len(&self) -> usize {
        self.width_mults.len() * self.depth_mults.len()
    }

    /// Whether an axis is empty (degenerate space).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The QSL-facing name of the first empty model axis, if any.
    pub fn empty_axis(&self) -> Option<&'static str> {
        if self.width_mults.is_empty() {
            Some("width")
        } else if self.depth_mults.is_empty() {
            Some("depth")
        } else {
            None
        }
    }

    /// The single validation rule for model axes — shared by JSON
    /// deserialization, the explorer, and (in message spirit) the QSL
    /// resolver and CLI flag parsers, so no path can accept axes
    /// another rejects: both axes non-empty, widths positive and
    /// finite, depths at least 1.
    pub fn ensure_valid(&self) -> Result<()> {
        if let Some(axis) = self.empty_axis() {
            return Err(Error::InvalidConfig(format!(
                "model axis '{axis}' lists no candidate values: the design space is empty"
            )));
        }
        if let Some(bad) = self.width_mults.iter().find(|w| !w.is_finite() || **w <= 0.0) {
            return Err(Error::InvalidConfig(format!(
                "model axis 'width' has a non-positive multiplier ({bad}); width multipliers \
                 must be positive finite numbers"
            )));
        }
        if self.depth_mults.contains(&0) {
            return Err(Error::InvalidConfig(
                "model axis 'depth' has a zero multiplier; depth multipliers must be at least 1"
                    .into(),
            ));
        }
        Ok(())
    }

    /// Decode the `index`-th variant (width outermost, depth innermost);
    /// `None` when `index >= self.len()`.
    pub fn variant(&self, index: usize) -> Option<ModelVariant> {
        if index >= self.len() {
            return None;
        }
        let depth = self.depth_mults[index % self.depth_mults.len()];
        let width = self.width_mults[index / self.depth_mults.len()];
        Some(ModelVariant { width, depth })
    }

    /// Serialize as the `"model_axes"` payload of a joint design space.
    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "width_mults",
                Json::Arr(self.width_mults.iter().map(|&w| num(w)).collect()),
            ),
            (
                "depth_mults",
                Json::Arr(self.depth_mults.iter().map(|&d| num(d as f64)).collect()),
            ),
        ])
    }

    /// Deserialize from [`Self::to_json`] output.
    pub fn from_json(json: &Json) -> Result<Self> {
        let widths = json
            .get("width_mults")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::ParseError("model_axes missing 'width_mults'".into()))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .filter(|w| w.is_finite() && *w > 0.0)
                    .ok_or_else(|| {
                        Error::ParseError("width multipliers must be positive numbers".into())
                    })
            })
            .collect::<Result<Vec<f64>>>()?;
        let depths = json
            .get("depth_mults")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::ParseError("model_axes missing 'depth_mults'".into()))?
            .iter()
            .map(|v| {
                v.as_i64()
                    .filter(|d| *d >= 1)
                    .map(|d| d as usize)
                    .ok_or_else(|| {
                        Error::ParseError("depth multipliers must be positive integers".into())
                    })
            })
            .collect::<Result<Vec<usize>>>()?;
        let axes = Self { width_mults: widths, depth_mults: depths };
        axes.ensure_valid()?;
        Ok(axes)
    }
}

/// One concrete model scaling: the (width, depth) pair a joint design
/// point applies to every base workload model (see
/// [`crate::dnn::scale_model`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelVariant {
    /// Channel-width multiplier (> 0; `1.0` = base widths).
    pub width: f64,
    /// Depth multiplier (≥ 1; `1` = base depth).
    pub depth: usize,
}

impl ModelVariant {
    /// The base model itself (no scaling applied).
    pub fn is_identity(&self) -> bool {
        self.width == 1.0 && self.depth == 1
    }

    /// Short human-readable label (`"w0.5d2"`), used in summaries.
    pub fn label(&self) -> String {
        format!("w{}d{}", self.width, self.depth)
    }
}

/// One decoded joint design point: the model scaling to apply and the
/// hardware configuration to evaluate it on.
#[derive(Debug, Clone, PartialEq)]
pub struct JointPoint {
    /// The model-axes variant of this point.
    pub variant: ModelVariant,
    /// The hardware design point.
    pub config: AcceleratorConfig,
}

/// The joint hardware × model design space: a [`SweepSpec`] crossed with
/// [`ModelAxes`]. Joint indices put the model variant in the outermost
/// mixed-radix digit (`index = variant_index * hw.len() + hw_index`), so
/// with trivial model axes the joint indices *are* the hardware indices
/// — pre-joint campaigns, journals, and fingerprints are unchanged.
///
/// ```
/// use qadam::arch::{DesignSpace, ModelAxes, SweepSpec};
///
/// let space = DesignSpace::new(
///     SweepSpec::tiny(),
///     ModelAxes { width_mults: vec![0.5, 1.0], depth_mults: vec![1] },
/// );
/// assert_eq!(space.len(), 2 * SweepSpec::tiny().len());
/// // The first hardware block carries the first variant…
/// assert_eq!(space.get(0).unwrap().variant.width, 0.5);
/// // …and a trivial space is fingerprint-identical to its sweep.
/// let trivial = DesignSpace::from(SweepSpec::tiny());
/// assert_eq!(trivial.fingerprint(), SweepSpec::tiny().fingerprint());
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct DesignSpace {
    /// The hardware axes.
    pub hw: SweepSpec,
    /// The model-hyperparameter axes.
    pub model: ModelAxes,
}

impl From<SweepSpec> for DesignSpace {
    fn from(hw: SweepSpec) -> Self {
        Self { hw, model: ModelAxes::default() }
    }
}

impl DesignSpace {
    /// Build a joint space from hardware and model axes.
    pub fn new(hw: SweepSpec, model: ModelAxes) -> Self {
        Self { hw, model }
    }

    /// Number of joint design points (hardware points × model variants).
    pub fn len(&self) -> usize {
        self.hw.len() * self.model.len()
    }

    /// Whether the joint space is degenerate (any empty axis).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Reject a degenerate space with an error *naming* the offending
    /// axis (`sweep axis 'glb_kib'` / `model axis 'width'`), so a
    /// mis-built campaign says exactly what to fix.
    pub fn ensure_nonempty(&self) -> Result<()> {
        if let Some(axis) = self.hw.empty_axis() {
            return Err(Error::InvalidConfig(format!(
                "sweep axis '{axis}' lists no candidate values: the design space is empty"
            )));
        }
        self.model.ensure_valid()
    }

    /// The model-variant digit of a joint index.
    ///
    /// # Panics
    /// If the hardware space is empty (guard with
    /// [`Self::ensure_nonempty`] first).
    pub fn variant_index(&self, index: usize) -> usize {
        index / self.hw.len()
    }

    /// The hardware digit of a joint index.
    ///
    /// # Panics
    /// If the hardware space is empty.
    pub fn hw_index(&self, index: usize) -> usize {
        index % self.hw.len()
    }

    /// Decode the variant of joint point `index` (`None` out of range).
    pub fn variant_of(&self, index: usize) -> Option<ModelVariant> {
        if index >= self.len() {
            return None;
        }
        self.model.variant(self.variant_index(index))
    }

    /// Decode the `index`-th joint design point without materializing
    /// anything; `None` when `index >= self.len()`. Order: model
    /// variants outermost (each variant's full hardware block is
    /// contiguous), hardware cross-product order within a block.
    pub fn get(&self, index: usize) -> Option<JointPoint> {
        if index >= self.len() {
            return None;
        }
        let variant = self.model.variant(self.variant_index(index))?;
        let config = self.hw.get(self.hw_index(index))?;
        Some(JointPoint { variant, config })
    }

    /// Lazy iterator over the joint space (O(1) memory).
    // `index < len`, so every joint index decodes to a point (and the
    // iterator must stay ExactSize, ruling out filter_map).
    #[allow(clippy::expect_used)]
    pub fn iter(&self) -> impl ExactSizeIterator<Item = JointPoint> + '_ {
        (0..self.len()).map(move |index| self.get(index).expect("index within joint space"))
    }

    /// Lazy round-robin shard view of the joint space — the joint
    /// points whose index `i` satisfies `i % num_shards == shard`, in
    /// index order (the same partition [`SweepSpec::shard_iter`] gives
    /// a bare hardware space).
    ///
    /// # Panics
    /// If `num_shards == 0` or `shard >= num_shards`.
    // `shard + pos * num_shards < len` by the `count` arithmetic below.
    #[allow(clippy::expect_used)]
    pub fn shard_iter(
        &self,
        shard: usize,
        num_shards: usize,
    ) -> impl ExactSizeIterator<Item = JointPoint> + '_ {
        assert!(
            num_shards > 0 && shard < num_shards,
            "shard {shard} out of range for {num_shards} shards"
        );
        let len = self.len();
        let count = if shard < len { (len - shard).div_ceil(num_shards) } else { 0 };
        (0..count).map(move |pos| {
            self.get(shard + pos * num_shards).expect("shard index within joint space")
        })
    }

    /// Serialize to JSON. With trivial model axes the rendering is
    /// *exactly* [`SweepSpec::to_json`] — no `"model_axes"` key — so
    /// pre-joint sweeps, files, and fingerprints are preserved; joint
    /// spaces add the `"model_axes"` object.
    pub fn to_json(&self) -> Json {
        let hw = self.hw.to_json();
        if self.model.is_trivial() {
            return hw;
        }
        let Json::Obj(mut fields) = hw else { unreachable!("SweepSpec::to_json is an object") };
        fields.insert("model_axes".into(), self.model.to_json());
        Json::Obj(fields)
    }

    /// Deserialize from [`Self::to_json`] output (a bare sweep object,
    /// or one carrying a `"model_axes"` key).
    pub fn from_json(json: &Json) -> Result<Self> {
        let hw = SweepSpec::from_json(json)?;
        let model = match json.get("model_axes") {
            None => ModelAxes::default(),
            Some(axes) => ModelAxes::from_json(axes)?,
        };
        Ok(Self { hw, model })
    }

    /// Load a joint space from a JSON file (the `--sweep <file>` config
    /// format; a plain hardware sweep file loads with trivial axes).
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let json = Json::parse(&text)?;
        Self::from_json(&json)
    }

    /// Stable 64-bit fingerprint of the *joint* identity: FNV-1a over
    /// the canonical JSON rendering. Equal to
    /// [`SweepSpec::fingerprint`] when the model axes are trivial, so
    /// hardware-only campaign journals and frontier bindings stay
    /// interchangeable with pre-joint builds; any model-axes change
    /// produces a different fingerprint.
    pub fn fingerprint(&self) -> u64 {
        crate::util::fnv1a_64(self.to_json().to_string_canonical().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cross_product_size() {
        let spec = SweepSpec::default();
        assert_eq!(spec.enumerate().len(), spec.len());
        assert_eq!(spec.iter().len(), spec.len());
        assert_eq!(spec.len(), 4 * 5 * 4 * 4 * 3);
    }

    #[test]
    fn lazy_iter_matches_nested_loops() {
        // Golden reference: the eager nested-loop cross-product the lazy
        // decoder must reproduce exactly (order included).
        let spec = SweepSpec::default();
        let mut golden = Vec::with_capacity(spec.len());
        for &pe in &spec.pe_types {
            for &(rows, cols) in &spec.array_dims {
                for &glb_kib in &spec.glb_kib {
                    for &spad in &spec.spads {
                        for &dram_bw_gbps in &spec.dram_bw_gbps {
                            for &clock_ghz in &spec.clock_ghz {
                                golden.push(AcceleratorConfig {
                                    pe,
                                    rows,
                                    cols,
                                    spad,
                                    glb_kib,
                                    dram_bw_gbps,
                                    clock_ghz,
                                });
                            }
                        }
                    }
                }
            }
        }
        let lazy: Vec<AcceleratorConfig> = spec.iter().collect();
        assert_eq!(lazy, golden);
    }

    #[test]
    fn get_addresses_points_randomly() {
        let spec = SweepSpec::default();
        let all = spec.enumerate();
        for index in [0, 1, 7, 63, spec.len() - 1] {
            assert_eq!(spec.get(index).unwrap(), all[index], "index {index}");
        }
        assert!(spec.get(spec.len()).is_none());
    }

    #[test]
    fn iter_nth_matches_skip() {
        let spec = SweepSpec::default();
        let via_nth = spec.iter().nth(17).unwrap();
        let via_skip = spec.enumerate()[17].clone();
        assert_eq!(via_nth, via_skip);
        // nth past the end terminates cleanly and leaves a sane length.
        let mut overshot = spec.iter();
        assert!(overshot.nth(spec.len() + 5).is_none());
        assert_eq!(overshot.len(), 0);
    }

    #[test]
    fn iter_is_double_ended() {
        let spec = SweepSpec::tiny();
        let forward: Vec<String> = spec.iter().map(|c| c.id()).collect();
        let mut backward: Vec<String> = spec.iter().rev().map(|c| c.id()).collect();
        backward.reverse();
        assert_eq!(forward, backward);
    }

    #[test]
    fn all_enumerated_valid() {
        for cfg in &SweepSpec::default() {
            assert!(cfg.validate().is_ok(), "invalid config {}", cfg.id());
        }
    }

    #[test]
    fn shards_partition_the_space() {
        let spec = SweepSpec::tiny();
        let all = spec.enumerate();
        let mut recombined: Vec<_> = (0..3)
            .flat_map(|shard| spec.shard_iter(shard, 3))
            .map(|c| c.id())
            .collect();
        recombined.sort();
        let mut expected: Vec<_> = all.iter().map(|c| c.id()).collect();
        expected.sort();
        assert_eq!(recombined, expected);
    }

    #[test]
    fn shard_iter_matches_skip_step_by() {
        let spec = SweepSpec::default();
        for (shard, num_shards) in [(0, 1), (0, 3), (2, 3), (4, 5)] {
            let lazy: Vec<String> =
                spec.shard_iter(shard, num_shards).map(|c| c.id()).collect();
            let reference: Vec<String> = spec
                .iter()
                .skip(shard)
                .step_by(num_shards)
                .map(|c| c.id())
                .collect();
            assert_eq!(lazy, reference, "shard {shard}/{num_shards}");
            assert_eq!(
                spec.shard_iter(shard, num_shards).len(),
                reference.len(),
                "shard {shard}/{num_shards} ExactSizeIterator length"
            );
        }
    }

    fn wide_axes() -> ModelAxes {
        ModelAxes { width_mults: vec![0.5, 1.0], depth_mults: vec![1, 2, 3] }
    }

    #[test]
    fn model_axes_default_is_trivial() {
        let axes = ModelAxes::default();
        assert!(axes.is_trivial());
        assert_eq!(axes.len(), 1);
        assert_eq!(axes.variant(0), Some(ModelVariant { width: 1.0, depth: 1 }));
        assert!(axes.variant(0).unwrap().is_identity());
        assert!(!wide_axes().is_trivial());
        assert_eq!(wide_axes().len(), 6);
    }

    #[test]
    fn model_axes_decode_width_outermost() {
        let axes = wide_axes();
        let variants: Vec<(f64, usize)> =
            (0..axes.len()).map(|i| axes.variant(i).map(|v| (v.width, v.depth)).unwrap()).collect();
        assert_eq!(
            variants,
            vec![(0.5, 1), (0.5, 2), (0.5, 3), (1.0, 1), (1.0, 2), (1.0, 3)]
        );
        assert!(axes.variant(axes.len()).is_none());
    }

    #[test]
    fn joint_space_indices_are_variant_major() {
        let space = DesignSpace::new(SweepSpec::tiny(), wide_axes());
        assert_eq!(space.len(), SweepSpec::tiny().len() * 6);
        // Within a variant block the hardware order is the sweep order.
        let hw_len = space.hw.len();
        for index in 0..space.len() {
            let point = space.get(index).unwrap();
            assert_eq!(point.config, space.hw.get(index % hw_len).unwrap());
            assert_eq!(point.variant, space.model.variant(index / hw_len).unwrap());
            assert_eq!(space.variant_index(index), index / hw_len);
            assert_eq!(space.hw_index(index), index % hw_len);
        }
        assert!(space.get(space.len()).is_none());
    }

    #[test]
    fn trivial_joint_space_matches_bare_sweep() {
        let spec = SweepSpec::tiny();
        let space = DesignSpace::from(spec.clone());
        assert_eq!(space.len(), spec.len());
        for (joint, hw) in space.iter().zip(spec.iter()) {
            assert!(joint.variant.is_identity());
            assert_eq!(joint.config, hw);
        }
        // Same canonical JSON, same fingerprint: journals interchange.
        assert_eq!(
            space.to_json().to_string_canonical(),
            spec.to_json().to_string_canonical()
        );
        assert_eq!(space.fingerprint(), spec.fingerprint());
    }

    #[test]
    fn joint_space_json_round_trips_and_fingerprints_axes() {
        let space = DesignSpace::new(SweepSpec::tiny(), wide_axes());
        let parsed = DesignSpace::from_json(&space.to_json()).unwrap();
        assert_eq!(parsed, space);
        assert_eq!(parsed.fingerprint(), space.fingerprint());
        // Any model-axes change moves the fingerprint.
        let mut deeper = space.clone();
        deeper.model.depth_mults.push(4);
        assert_ne!(space.fingerprint(), deeper.fingerprint());
        assert_ne!(space.fingerprint(), DesignSpace::from(SweepSpec::tiny()).fingerprint());
    }

    #[test]
    fn joint_shards_partition_the_space() {
        let space = DesignSpace::new(SweepSpec::tiny(), wide_axes());
        for num_shards in [1, 2, 5] {
            let mut recombined: Vec<String> = (0..num_shards)
                .flat_map(|shard| space.shard_iter(shard, num_shards))
                .map(|p| format!("{}/{}", p.variant.label(), p.config.id()))
                .collect();
            recombined.sort();
            let mut expected: Vec<String> = space
                .iter()
                .map(|p| format!("{}/{}", p.variant.label(), p.config.id()))
                .collect();
            expected.sort();
            assert_eq!(recombined, expected, "{num_shards} shards");
        }
    }

    #[test]
    fn empty_axes_are_named() {
        let mut spec = SweepSpec::tiny();
        spec.glb_kib.clear();
        assert_eq!(spec.empty_axis(), Some("glb_kib"));
        let err = DesignSpace::from(spec).ensure_nonempty().unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        assert!(err.to_string().contains("'glb_kib'"), "{err}");
        let space = DesignSpace::new(
            SweepSpec::tiny(),
            ModelAxes { width_mults: vec![], depth_mults: vec![1] },
        );
        let err = space.ensure_nonempty().unwrap_err();
        assert!(err.to_string().contains("model axis 'width'"), "{err}");
    }

    #[test]
    fn for_pe_restricts() {
        let spec = SweepSpec::default().for_pe(PeType::Fp32);
        assert!(spec.iter().all(|c| c.pe == PeType::Fp32));
    }

    #[test]
    fn json_roundtrip() {
        let spec = SweepSpec::default();
        let parsed = SweepSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(parsed.len(), spec.len());
        let a: Vec<String> = spec.iter().map(|c| c.id()).collect();
        let b: Vec<String> = parsed.iter().map(|c| c.id()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn partial_json_overrides_one_axis() {
        let json = Json::parse(r#"{"pe_types": ["LightPE-1"]}"#).unwrap();
        let spec = SweepSpec::from_json(&json).unwrap();
        assert_eq!(spec.pe_types, vec![PeType::LightPe1]);
        // Other axes keep defaults.
        assert_eq!(spec.glb_kib, SweepSpec::default().glb_kib);
    }

    #[test]
    fn bad_json_rejected_with_typed_errors() {
        for (text, kind) in [
            (r#"{"pe_types": ["INT99"]}"#, "parse_error"),
            (r#"{"array_dims": [[0, 8]]}"#, "parse_error"),
            (r#"{"glb_kib": []}"#, "invalid_config"),
        ] {
            let json = Json::parse(text).unwrap();
            let err = SweepSpec::from_json(&json).unwrap_err();
            assert_eq!(err.kind(), kind, "{text}");
        }
    }

    #[test]
    fn from_file_roundtrip() {
        let dir = std::env::temp_dir().join("qadam_sweep_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sweep.json");
        std::fs::write(&path, SweepSpec::tiny().to_json().to_string_pretty()).unwrap();
        let spec = SweepSpec::from_file(&path).unwrap();
        assert_eq!(spec.len(), SweepSpec::tiny().len());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn from_file_missing_is_io_error() {
        let err =
            SweepSpec::from_file(std::path::Path::new("/nonexistent/sweep.json")).unwrap_err();
        assert_eq!(err.kind(), "io");
    }

    #[test]
    fn fingerprint_is_stable_and_axis_sensitive() {
        let spec = SweepSpec::tiny();
        assert_eq!(spec.fingerprint(), SweepSpec::tiny().fingerprint());
        let mut wider = SweepSpec::tiny();
        wider.glb_kib.push(256);
        assert_ne!(spec.fingerprint(), wider.fingerprint());
        let mut faster = SweepSpec::tiny();
        faster.clock_ghz = vec![1.5];
        assert_ne!(spec.fingerprint(), faster.fingerprint());
        // Round-tripping through JSON preserves the fingerprint.
        let reparsed = SweepSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec.fingerprint(), reparsed.fingerprint());
    }

    #[test]
    fn unique_ids() {
        let mut ids: Vec<_> = SweepSpec::default().iter().map(|c| c.id()).collect();
        let total = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), total, "config ids must be unique");
    }
}
