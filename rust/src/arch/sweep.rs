//! Design-space sweep enumeration (§III-C).
//!
//! A [`SweepSpec`] lists candidate values per axis; iteration yields the
//! full cross-product as concrete [`AcceleratorConfig`]s. The space is
//! *lazily* enumerated: [`SweepSpec::iter`] decodes design points from a
//! mixed-radix index in O(1) memory, [`SweepSpec::get`] addresses any
//! point directly, and [`SweepSpec::shard_iter`] exposes a round-robin
//! shard view without materializing the space (the coordinator's
//! leader/worker split, and the substrate for future distributed shards).
//! The default space mirrors the paper's: 4 PE types × array sizes ×
//! global buffer sizes × scratchpad variants.

use super::{AcceleratorConfig, ScratchpadCfg};
use crate::error::{Error, Result};
use crate::quant::PeType;
use crate::util::json::{num, obj, s, Json};

/// Candidate values per design-space axis.
///
/// The cross-product is enumerated lazily: [`Self::get`] decodes any
/// point from its mixed-radix index in O(1), so iteration, random
/// access, and shard views never materialize the space.
///
/// ```
/// use qadam::arch::SweepSpec;
///
/// let spec = SweepSpec::tiny();
/// assert_eq!(spec.len(), 4); // 2 PE types × 2 array sizes
/// // Random access agrees with iteration order.
/// let third = spec.get(2).unwrap();
/// assert_eq!(spec.iter().nth(2).unwrap(), third);
/// // Shards partition the space without materializing it.
/// let counts: usize = (0..3).map(|s| spec.shard_iter(s, 3).len()).sum();
/// assert_eq!(counts, spec.len());
/// ```
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Candidate PE types.
    pub pe_types: Vec<PeType>,
    /// (rows, cols) pairs.
    pub array_dims: Vec<(usize, usize)>,
    /// Candidate global-buffer capacities (KiB).
    pub glb_kib: Vec<usize>,
    /// Candidate per-PE scratchpad configurations.
    pub spads: Vec<ScratchpadCfg>,
    /// Candidate DRAM bandwidths (GB/s).
    pub dram_bw_gbps: Vec<f64>,
    /// Candidate clock targets (GHz).
    pub clock_ghz: Vec<f64>,
}

impl Default for SweepSpec {
    fn default() -> Self {
        Self {
            pe_types: PeType::ALL.to_vec(),
            array_dims: vec![(8, 8), (12, 14), (16, 16), (24, 24), (32, 32)],
            glb_kib: vec![64, 128, 256, 512],
            spads: vec![
                ScratchpadCfg { ifmap_entries: 6, filter_entries: 28, psum_entries: 8 },
                ScratchpadCfg { ifmap_entries: 12, filter_entries: 112, psum_entries: 16 },
                ScratchpadCfg { ifmap_entries: 12, filter_entries: 224, psum_entries: 24 },
                ScratchpadCfg { ifmap_entries: 24, filter_entries: 448, psum_entries: 32 },
            ],
            dram_bw_gbps: vec![8.0, 16.0, 32.0],
            clock_ghz: vec![2.0],
        }
    }
}

impl SweepSpec {
    /// A small spec for fast tests (2 PE types × 2 arrays × 1 of the rest).
    pub fn tiny() -> Self {
        Self {
            pe_types: vec![PeType::Int16, PeType::LightPe1],
            array_dims: vec![(8, 8), (16, 16)],
            glb_kib: vec![128],
            spads: vec![ScratchpadCfg::default()],
            dram_bw_gbps: vec![8.0],
            clock_ghz: vec![2.0],
        }
    }

    /// Restrict to a single PE type (used by per-type model fitting).
    pub fn for_pe(mut self, pe: PeType) -> Self {
        self.pe_types = vec![pe];
        self
    }

    /// Number of design points in the cross-product.
    pub fn len(&self) -> usize {
        self.pe_types.len()
            * self.array_dims.len()
            * self.glb_kib.len()
            * self.spads.len()
            * self.dram_bw_gbps.len()
            * self.clock_ghz.len()
    }

    /// Whether the spec is degenerate (any empty axis).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Decode the `index`-th design point of the cross-product without
    /// materializing anything. Point order matches nested loops with
    /// `pe_types` outermost and `clock_ghz` innermost; `None` when
    /// `index >= self.len()`.
    pub fn get(&self, index: usize) -> Option<AcceleratorConfig> {
        if index >= self.len() {
            return None;
        }
        // Mixed-radix decode, least-significant (innermost) axis first.
        let mut rest = index;
        let mut digit = |len: usize| {
            let d = rest % len;
            rest /= len;
            d
        };
        let clock_ghz = self.clock_ghz[digit(self.clock_ghz.len())];
        let dram_bw_gbps = self.dram_bw_gbps[digit(self.dram_bw_gbps.len())];
        let spad = self.spads[digit(self.spads.len())];
        let glb_kib = self.glb_kib[digit(self.glb_kib.len())];
        let (rows, cols) = self.array_dims[digit(self.array_dims.len())];
        let pe = self.pe_types[rest];
        Some(AcceleratorConfig { pe, rows, cols, spad, glb_kib, dram_bw_gbps, clock_ghz })
    }

    /// Lazy iterator over the cross-product (O(1) memory; `nth` is O(1)).
    pub fn iter(&self) -> SweepIter<'_> {
        SweepIter { spec: self, next: 0, end: self.len() }
    }

    /// Lazy round-robin shard view: the design points whose index `i`
    /// satisfies `i % num_shards == shard`, in index order — the same
    /// points `iter().skip(shard).step_by(num_shards)` would yield, but
    /// index-addressed so it stays O(1) per point.
    ///
    /// # Panics
    /// If `num_shards == 0` or `shard >= num_shards`.
    pub fn shard_iter(
        &self,
        shard: usize,
        num_shards: usize,
    ) -> impl ExactSizeIterator<Item = AcceleratorConfig> + '_ {
        assert!(
            num_shards > 0 && shard < num_shards,
            "shard {shard} out of range for {num_shards} shards"
        );
        let len = self.len();
        let count = if shard < len { (len - shard).div_ceil(num_shards) } else { 0 };
        (0..count).map(move |pos| {
            self.get(shard + pos * num_shards).expect("shard index within cross-product")
        })
    }

    /// Materialize the full cross-product. Prefer [`Self::iter`] on hot
    /// paths — this allocates one `Vec` entry per design point.
    pub fn enumerate(&self) -> Vec<AcceleratorConfig> {
        self.iter().collect()
    }

    /// Serialize to JSON (the `--sweep <file>` config format).
    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "pe_types",
                Json::Arr(self.pe_types.iter().map(|p| s(p.name())).collect()),
            ),
            (
                "array_dims",
                Json::Arr(
                    self.array_dims
                        .iter()
                        .map(|&(r, c)| Json::Arr(vec![num(r as f64), num(c as f64)]))
                        .collect(),
                ),
            ),
            (
                "glb_kib",
                Json::Arr(self.glb_kib.iter().map(|&g| num(g as f64)).collect()),
            ),
            (
                "spads",
                Json::Arr(
                    self.spads
                        .iter()
                        .map(|sp| {
                            obj(vec![
                                ("ifmap", num(sp.ifmap_entries as f64)),
                                ("filter", num(sp.filter_entries as f64)),
                                ("psum", num(sp.psum_entries as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "dram_bw_gbps",
                Json::Arr(self.dram_bw_gbps.iter().map(|&b| num(b)).collect()),
            ),
            (
                "clock_ghz",
                Json::Arr(self.clock_ghz.iter().map(|&c| num(c)).collect()),
            ),
        ])
    }

    /// Deserialize from the JSON produced by [`Self::to_json`]. Missing
    /// axes fall back to the defaults, so config files can override only
    /// the axes they care about.
    pub fn from_json(json: &Json) -> Result<Self> {
        let mut spec = SweepSpec::default();
        if let Some(items) = json.get("pe_types").and_then(Json::as_arr) {
            spec.pe_types = items
                .iter()
                .map(|v| {
                    v.as_str()
                        .and_then(PeType::parse)
                        .ok_or_else(|| Error::ParseError(format!("bad pe type {v:?}")))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(items) = json.get("array_dims").and_then(Json::as_arr) {
            spec.array_dims = items
                .iter()
                .map(|v| {
                    let pair = v.as_arr().ok_or_else(|| {
                        Error::ParseError("array_dims entries must be [rows, cols]".into())
                    })?;
                    match (pair.first().and_then(Json::as_i64), pair.get(1).and_then(Json::as_i64))
                    {
                        (Some(r), Some(c)) if r > 0 && c > 0 => Ok((r as usize, c as usize)),
                        _ => Err(Error::ParseError(
                            "array_dims entries must be positive integers".into(),
                        )),
                    }
                })
                .collect::<Result<_>>()?;
        }
        if let Some(items) = json.get("glb_kib").and_then(Json::as_arr) {
            spec.glb_kib = items
                .iter()
                .map(|v| {
                    v.as_i64()
                        .map(|g| g as usize)
                        .ok_or_else(|| Error::ParseError("bad glb_kib".into()))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(items) = json.get("spads").and_then(Json::as_arr) {
            spec.spads = items
                .iter()
                .map(|v| {
                    let field = |k: &str| {
                        v.get(k)
                            .and_then(Json::as_i64)
                            .map(|x| x as usize)
                            .ok_or_else(|| Error::ParseError(format!("spad entry missing '{k}'")))
                    };
                    Ok(ScratchpadCfg {
                        ifmap_entries: field("ifmap")?,
                        filter_entries: field("filter")?,
                        psum_entries: field("psum")?,
                    })
                })
                .collect::<Result<_>>()?;
        }
        if let Some(items) = json.get("dram_bw_gbps").and_then(Json::as_arr) {
            spec.dram_bw_gbps = items
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| Error::ParseError("bad dram_bw_gbps".into())))
                .collect::<Result<_>>()?;
        }
        if let Some(items) = json.get("clock_ghz").and_then(Json::as_arr) {
            spec.clock_ghz = items
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| Error::ParseError("bad clock_ghz".into())))
                .collect::<Result<_>>()?;
        }
        if spec.is_empty() {
            return Err(Error::InvalidConfig("sweep spec has an empty axis".into()));
        }
        Ok(spec)
    }

    /// Stable 64-bit fingerprint of the design space: FNV-1a over the
    /// canonical JSON rendering (sorted keys, shortest round-trip
    /// numbers), so it survives process restarts and platform changes.
    /// Checkpoint journals embed it to reject resumes against a different
    /// space (`explore::persist`).
    pub fn fingerprint(&self) -> u64 {
        crate::util::fnv1a_64(self.to_json().to_string_canonical().as_bytes())
    }

    /// Load a sweep from a JSON file.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let json = Json::parse(&text)?;
        Self::from_json(&json)
    }

    /// Enumerate only the i-th shard of `n` (round-robin).
    #[deprecated(
        since = "0.2.0",
        note = "materializes the shard; use the lazy `shard_iter` instead"
    )]
    pub fn enumerate_shard(&self, shard: usize, num_shards: usize) -> Vec<AcceleratorConfig> {
        self.shard_iter(shard, num_shards).collect()
    }
}

/// Lazy iterator over a [`SweepSpec`] cross-product (see [`SweepSpec::iter`]).
#[derive(Debug, Clone)]
pub struct SweepIter<'a> {
    spec: &'a SweepSpec,
    next: usize,
    end: usize,
}

impl Iterator for SweepIter<'_> {
    type Item = AcceleratorConfig;

    fn next(&mut self) -> Option<AcceleratorConfig> {
        if self.next >= self.end {
            return None;
        }
        let config = self.spec.get(self.next);
        self.next += 1;
        config
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let remaining = self.end - self.next;
        (remaining, Some(remaining))
    }

    fn nth(&mut self, n: usize) -> Option<AcceleratorConfig> {
        // Clamp so an overshooting skip cannot push `next` past `end`
        // (which would underflow `size_hint`).
        self.next = self.next.saturating_add(n).min(self.end);
        self.next()
    }
}

impl ExactSizeIterator for SweepIter<'_> {}

impl DoubleEndedIterator for SweepIter<'_> {
    fn next_back(&mut self) -> Option<AcceleratorConfig> {
        if self.next >= self.end {
            return None;
        }
        self.end -= 1;
        self.spec.get(self.end)
    }
}

impl<'a> IntoIterator for &'a SweepSpec {
    type Item = AcceleratorConfig;
    type IntoIter = SweepIter<'a>;

    fn into_iter(self) -> SweepIter<'a> {
        self.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cross_product_size() {
        let spec = SweepSpec::default();
        assert_eq!(spec.enumerate().len(), spec.len());
        assert_eq!(spec.iter().len(), spec.len());
        assert_eq!(spec.len(), 4 * 5 * 4 * 4 * 3);
    }

    #[test]
    fn lazy_iter_matches_nested_loops() {
        // Golden reference: the eager nested-loop cross-product the lazy
        // decoder must reproduce exactly (order included).
        let spec = SweepSpec::default();
        let mut golden = Vec::with_capacity(spec.len());
        for &pe in &spec.pe_types {
            for &(rows, cols) in &spec.array_dims {
                for &glb_kib in &spec.glb_kib {
                    for &spad in &spec.spads {
                        for &dram_bw_gbps in &spec.dram_bw_gbps {
                            for &clock_ghz in &spec.clock_ghz {
                                golden.push(AcceleratorConfig {
                                    pe,
                                    rows,
                                    cols,
                                    spad,
                                    glb_kib,
                                    dram_bw_gbps,
                                    clock_ghz,
                                });
                            }
                        }
                    }
                }
            }
        }
        let lazy: Vec<AcceleratorConfig> = spec.iter().collect();
        assert_eq!(lazy, golden);
    }

    #[test]
    fn get_addresses_points_randomly() {
        let spec = SweepSpec::default();
        let all = spec.enumerate();
        for index in [0, 1, 7, 63, spec.len() - 1] {
            assert_eq!(spec.get(index).unwrap(), all[index], "index {index}");
        }
        assert!(spec.get(spec.len()).is_none());
    }

    #[test]
    fn iter_nth_matches_skip() {
        let spec = SweepSpec::default();
        let via_nth = spec.iter().nth(17).unwrap();
        let via_skip = spec.enumerate()[17].clone();
        assert_eq!(via_nth, via_skip);
        // nth past the end terminates cleanly and leaves a sane length.
        let mut overshot = spec.iter();
        assert!(overshot.nth(spec.len() + 5).is_none());
        assert_eq!(overshot.len(), 0);
    }

    #[test]
    fn iter_is_double_ended() {
        let spec = SweepSpec::tiny();
        let forward: Vec<String> = spec.iter().map(|c| c.id()).collect();
        let mut backward: Vec<String> = spec.iter().rev().map(|c| c.id()).collect();
        backward.reverse();
        assert_eq!(forward, backward);
    }

    #[test]
    fn all_enumerated_valid() {
        for cfg in &SweepSpec::default() {
            assert!(cfg.validate().is_ok(), "invalid config {}", cfg.id());
        }
    }

    #[test]
    fn shards_partition_the_space() {
        let spec = SweepSpec::tiny();
        let all = spec.enumerate();
        let mut recombined: Vec<_> = (0..3)
            .flat_map(|shard| spec.shard_iter(shard, 3))
            .map(|c| c.id())
            .collect();
        recombined.sort();
        let mut expected: Vec<_> = all.iter().map(|c| c.id()).collect();
        expected.sort();
        assert_eq!(recombined, expected);
    }

    #[test]
    fn shard_iter_matches_skip_step_by() {
        let spec = SweepSpec::default();
        for (shard, num_shards) in [(0, 1), (0, 3), (2, 3), (4, 5)] {
            let lazy: Vec<String> =
                spec.shard_iter(shard, num_shards).map(|c| c.id()).collect();
            let reference: Vec<String> = spec
                .iter()
                .skip(shard)
                .step_by(num_shards)
                .map(|c| c.id())
                .collect();
            assert_eq!(lazy, reference, "shard {shard}/{num_shards}");
            assert_eq!(
                spec.shard_iter(shard, num_shards).len(),
                reference.len(),
                "shard {shard}/{num_shards} ExactSizeIterator length"
            );
        }
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_enumerate_shard_still_partitions() {
        let spec = SweepSpec::tiny();
        let mut recombined: Vec<_> = (0..3)
            .flat_map(|shard| spec.enumerate_shard(shard, 3))
            .map(|c| c.id())
            .collect();
        recombined.sort();
        let mut expected: Vec<_> = spec.iter().map(|c| c.id()).collect();
        expected.sort();
        assert_eq!(recombined, expected);
    }

    #[test]
    fn for_pe_restricts() {
        let spec = SweepSpec::default().for_pe(PeType::Fp32);
        assert!(spec.iter().all(|c| c.pe == PeType::Fp32));
    }

    #[test]
    fn json_roundtrip() {
        let spec = SweepSpec::default();
        let parsed = SweepSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(parsed.len(), spec.len());
        let a: Vec<String> = spec.iter().map(|c| c.id()).collect();
        let b: Vec<String> = parsed.iter().map(|c| c.id()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn partial_json_overrides_one_axis() {
        let json = Json::parse(r#"{"pe_types": ["LightPE-1"]}"#).unwrap();
        let spec = SweepSpec::from_json(&json).unwrap();
        assert_eq!(spec.pe_types, vec![PeType::LightPe1]);
        // Other axes keep defaults.
        assert_eq!(spec.glb_kib, SweepSpec::default().glb_kib);
    }

    #[test]
    fn bad_json_rejected_with_typed_errors() {
        for (text, kind) in [
            (r#"{"pe_types": ["INT99"]}"#, "parse_error"),
            (r#"{"array_dims": [[0, 8]]}"#, "parse_error"),
            (r#"{"glb_kib": []}"#, "invalid_config"),
        ] {
            let json = Json::parse(text).unwrap();
            let err = SweepSpec::from_json(&json).unwrap_err();
            assert_eq!(err.kind(), kind, "{text}");
        }
    }

    #[test]
    fn from_file_roundtrip() {
        let dir = std::env::temp_dir().join("qadam_sweep_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sweep.json");
        std::fs::write(&path, SweepSpec::tiny().to_json().to_string_pretty()).unwrap();
        let spec = SweepSpec::from_file(&path).unwrap();
        assert_eq!(spec.len(), SweepSpec::tiny().len());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn from_file_missing_is_io_error() {
        let err =
            SweepSpec::from_file(std::path::Path::new("/nonexistent/sweep.json")).unwrap_err();
        assert_eq!(err.kind(), "io");
    }

    #[test]
    fn fingerprint_is_stable_and_axis_sensitive() {
        let spec = SweepSpec::tiny();
        assert_eq!(spec.fingerprint(), SweepSpec::tiny().fingerprint());
        let mut wider = SweepSpec::tiny();
        wider.glb_kib.push(256);
        assert_ne!(spec.fingerprint(), wider.fingerprint());
        let mut faster = SweepSpec::tiny();
        faster.clock_ghz = vec![1.5];
        assert_ne!(spec.fingerprint(), faster.fingerprint());
        // Round-tripping through JSON preserves the fingerprint.
        let reparsed = SweepSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec.fingerprint(), reparsed.fingerprint());
    }

    #[test]
    fn unique_ids() {
        let mut ids: Vec<_> = SweepSpec::default().iter().map(|c| c.id()).collect();
        let total = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), total, "config ids must be unique");
    }
}
