//! Design-space sweep enumeration (§III-C).
//!
//! A [`SweepSpec`] lists candidate values per axis; [`SweepSpec::enumerate`]
//! yields the full cross-product as concrete [`AcceleratorConfig`]s. The
//! default space mirrors the paper's: 4 PE types × array sizes × global
//! buffer sizes × scratchpad variants.

use super::{AcceleratorConfig, ScratchpadCfg};
use crate::quant::PeType;
use crate::util::json::{num, obj, s, Json};

/// Candidate values per design-space axis.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub pe_types: Vec<PeType>,
    /// (rows, cols) pairs.
    pub array_dims: Vec<(usize, usize)>,
    pub glb_kib: Vec<usize>,
    pub spads: Vec<ScratchpadCfg>,
    pub dram_bw_gbps: Vec<f64>,
    pub clock_ghz: Vec<f64>,
}

impl Default for SweepSpec {
    fn default() -> Self {
        Self {
            pe_types: PeType::ALL.to_vec(),
            array_dims: vec![(8, 8), (12, 14), (16, 16), (24, 24), (32, 32)],
            glb_kib: vec![64, 128, 256, 512],
            spads: vec![
                ScratchpadCfg { ifmap_entries: 6, filter_entries: 28, psum_entries: 8 },
                ScratchpadCfg { ifmap_entries: 12, filter_entries: 112, psum_entries: 16 },
                ScratchpadCfg { ifmap_entries: 12, filter_entries: 224, psum_entries: 24 },
                ScratchpadCfg { ifmap_entries: 24, filter_entries: 448, psum_entries: 32 },
            ],
            dram_bw_gbps: vec![8.0, 16.0, 32.0],
            clock_ghz: vec![2.0],
        }
    }
}

impl SweepSpec {
    /// A small spec for fast tests (2 PE types × 2 arrays × 1 of the rest).
    pub fn tiny() -> Self {
        Self {
            pe_types: vec![PeType::Int16, PeType::LightPe1],
            array_dims: vec![(8, 8), (16, 16)],
            glb_kib: vec![128],
            spads: vec![ScratchpadCfg::default()],
            dram_bw_gbps: vec![8.0],
            clock_ghz: vec![2.0],
        }
    }

    /// Restrict to a single PE type (used by per-type model fitting).
    pub fn for_pe(mut self, pe: PeType) -> Self {
        self.pe_types = vec![pe];
        self
    }

    /// Number of design points in the cross-product.
    pub fn len(&self) -> usize {
        self.pe_types.len()
            * self.array_dims.len()
            * self.glb_kib.len()
            * self.spads.len()
            * self.dram_bw_gbps.len()
            * self.clock_ghz.len()
    }

    /// Whether the spec is degenerate (any empty axis).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize the full cross-product.
    pub fn enumerate(&self) -> Vec<AcceleratorConfig> {
        let mut out = Vec::with_capacity(self.len());
        for &pe in &self.pe_types {
            for &(rows, cols) in &self.array_dims {
                for &glb_kib in &self.glb_kib {
                    for &spad in &self.spads {
                        for &dram_bw_gbps in &self.dram_bw_gbps {
                            for &clock_ghz in &self.clock_ghz {
                                out.push(AcceleratorConfig {
                                    pe,
                                    rows,
                                    cols,
                                    spad,
                                    glb_kib,
                                    dram_bw_gbps,
                                    clock_ghz,
                                });
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Serialize to JSON (the `--sweep <file>` config format).
    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "pe_types",
                Json::Arr(self.pe_types.iter().map(|p| s(p.name())).collect()),
            ),
            (
                "array_dims",
                Json::Arr(
                    self.array_dims
                        .iter()
                        .map(|&(r, c)| Json::Arr(vec![num(r as f64), num(c as f64)]))
                        .collect(),
                ),
            ),
            (
                "glb_kib",
                Json::Arr(self.glb_kib.iter().map(|&g| num(g as f64)).collect()),
            ),
            (
                "spads",
                Json::Arr(
                    self.spads
                        .iter()
                        .map(|sp| {
                            obj(vec![
                                ("ifmap", num(sp.ifmap_entries as f64)),
                                ("filter", num(sp.filter_entries as f64)),
                                ("psum", num(sp.psum_entries as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "dram_bw_gbps",
                Json::Arr(self.dram_bw_gbps.iter().map(|&b| num(b)).collect()),
            ),
            (
                "clock_ghz",
                Json::Arr(self.clock_ghz.iter().map(|&c| num(c)).collect()),
            ),
        ])
    }

    /// Deserialize from the JSON produced by [`Self::to_json`]. Missing
    /// axes fall back to the defaults, so config files can override only
    /// the axes they care about.
    pub fn from_json(json: &Json) -> Result<Self, String> {
        let mut spec = SweepSpec::default();
        if let Some(items) = json.get("pe_types").and_then(Json::as_arr) {
            spec.pe_types = items
                .iter()
                .map(|v| {
                    v.as_str()
                        .and_then(PeType::parse)
                        .ok_or_else(|| format!("bad pe type {v:?}"))
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(items) = json.get("array_dims").and_then(Json::as_arr) {
            spec.array_dims = items
                .iter()
                .map(|v| {
                    let pair = v.as_arr().ok_or("array_dims entries must be [rows, cols]")?;
                    match (pair.first().and_then(Json::as_i64), pair.get(1).and_then(Json::as_i64))
                    {
                        (Some(r), Some(c)) if r > 0 && c > 0 => Ok((r as usize, c as usize)),
                        _ => Err("array_dims entries must be positive integers".to_string()),
                    }
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(items) = json.get("glb_kib").and_then(Json::as_arr) {
            spec.glb_kib = items
                .iter()
                .map(|v| v.as_i64().map(|g| g as usize).ok_or("bad glb_kib"))
                .collect::<Result<_, _>>()?;
        }
        if let Some(items) = json.get("spads").and_then(Json::as_arr) {
            spec.spads = items
                .iter()
                .map(|v| {
                    let field = |k: &str| {
                        v.get(k)
                            .and_then(Json::as_i64)
                            .map(|x| x as usize)
                            .ok_or_else(|| format!("spad entry missing '{k}'"))
                    };
                    Ok::<_, String>(ScratchpadCfg {
                        ifmap_entries: field("ifmap")?,
                        filter_entries: field("filter")?,
                        psum_entries: field("psum")?,
                    })
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(items) = json.get("dram_bw_gbps").and_then(Json::as_arr) {
            spec.dram_bw_gbps =
                items.iter().map(|v| v.as_f64().ok_or("bad dram_bw_gbps")).collect::<Result<_, _>>()?;
        }
        if let Some(items) = json.get("clock_ghz").and_then(Json::as_arr) {
            spec.clock_ghz =
                items.iter().map(|v| v.as_f64().ok_or("bad clock_ghz")).collect::<Result<_, _>>()?;
        }
        if spec.is_empty() {
            return Err("sweep spec has an empty axis".into());
        }
        Ok(spec)
    }

    /// Load a sweep from a JSON file.
    pub fn from_file(path: &std::path::Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        let json = Json::parse(&text).map_err(|e| e.to_string())?;
        Self::from_json(&json)
    }

    /// Enumerate only the i-th shard of `n` (round-robin), for the
    /// coordinator's leader/worker split.
    pub fn enumerate_shard(&self, shard: usize, num_shards: usize) -> Vec<AcceleratorConfig> {
        assert!(num_shards > 0 && shard < num_shards);
        self.enumerate()
            .into_iter()
            .enumerate()
            .filter(|(i, _)| i % num_shards == shard)
            .map(|(_, c)| c)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cross_product_size() {
        let spec = SweepSpec::default();
        assert_eq!(spec.enumerate().len(), spec.len());
        assert_eq!(spec.len(), 4 * 5 * 4 * 4 * 3);
    }

    #[test]
    fn all_enumerated_valid() {
        for cfg in SweepSpec::default().enumerate() {
            assert!(cfg.validate().is_ok(), "invalid config {}", cfg.id());
        }
    }

    #[test]
    fn shards_partition_the_space() {
        let spec = SweepSpec::tiny();
        let all = spec.enumerate();
        let mut recombined: Vec<_> = (0..3)
            .flat_map(|shard| spec.enumerate_shard(shard, 3))
            .map(|c| c.id())
            .collect();
        recombined.sort();
        let mut expected: Vec<_> = all.iter().map(|c| c.id()).collect();
        expected.sort();
        assert_eq!(recombined, expected);
    }

    #[test]
    fn for_pe_restricts() {
        let spec = SweepSpec::default().for_pe(PeType::Fp32);
        assert!(spec.enumerate().iter().all(|c| c.pe == PeType::Fp32));
    }

    #[test]
    fn json_roundtrip() {
        let spec = SweepSpec::default();
        let parsed = SweepSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(parsed.len(), spec.len());
        let a: Vec<String> = spec.enumerate().iter().map(|c| c.id()).collect();
        let b: Vec<String> = parsed.enumerate().iter().map(|c| c.id()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn partial_json_overrides_one_axis() {
        let json = Json::parse(r#"{"pe_types": ["LightPE-1"]}"#).unwrap();
        let spec = SweepSpec::from_json(&json).unwrap();
        assert_eq!(spec.pe_types, vec![PeType::LightPe1]);
        // Other axes keep defaults.
        assert_eq!(spec.glb_kib, SweepSpec::default().glb_kib);
    }

    #[test]
    fn bad_json_rejected() {
        for text in [
            r#"{"pe_types": ["INT99"]}"#,
            r#"{"array_dims": [[0, 8]]}"#,
            r#"{"glb_kib": []}"#,
        ] {
            let json = Json::parse(text).unwrap();
            assert!(SweepSpec::from_json(&json).is_err(), "{text}");
        }
    }

    #[test]
    fn from_file_roundtrip() {
        let dir = std::env::temp_dir().join("qadam_sweep_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sweep.json");
        std::fs::write(&path, SweepSpec::tiny().to_json().to_string_pretty()).unwrap();
        let spec = SweepSpec::from_file(&path).unwrap();
        assert_eq!(spec.len(), SweepSpec::tiny().len());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unique_ids() {
        let all = SweepSpec::default().enumerate();
        let mut ids: Vec<_> = all.iter().map(|c| c.id()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), all.len(), "config ids must be unique");
    }
}
