//! Work-stealing-lite thread pool for CPU-bound evaluation jobs.
//!
//! Jobs are claimed through a shared atomic cursor (each worker grabs the
//! next unclaimed index), which self-balances when job costs vary — large
//! ImageNet models take ~50× longer to map than CIFAR ones, so static
//! chunking would idle half the pool. Results land in their input slots,
//! so output order equals input order regardless of scheduling.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of workers to use by default (leaves one core for the leader).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get().saturating_sub(1).max(1)).unwrap_or(4)
}

/// Run `f` over `jobs` on `workers` threads; results keep input order.
pub fn parallel_map<T, R, F>(jobs: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    assert!(workers >= 1);
    let n = jobs.len();
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let jobs_ref = &jobs;
    let f_ref = &f;
    let slots_ref = &slots;
    let cursor_ref = &cursor;
    std::thread::scope(|scope| {
        for _ in 0..workers.min(n.max(1)) {
            scope.spawn(move || loop {
                let index = cursor_ref.fetch_add(1, Ordering::Relaxed);
                if index >= n {
                    break;
                }
                let result = f_ref(&jobs_ref[index]);
                // Poisoning is recoverable here: the slot either holds the
                // completed result or is still None, and a panicking
                // sibling re-raises at scope exit anyway.
                *slots_ref[index].lock().unwrap_or_else(|e| e.into_inner()) = Some(result);
            });
        }
    });
    // The scope re-raises any worker panic before this point, so every
    // slot was filled by the cursor walk above.
    #[allow(clippy::expect_used)]
    let results = slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .unwrap_or_else(|e| e.into_inner())
                .expect("worker must fill its slot")
        })
        .collect();
    results
}

/// Progress counter shared between the leader and workers.
#[derive(Debug, Default)]
pub struct Progress {
    done: AtomicUsize,
    total: AtomicUsize,
}

impl Progress {
    /// New progress tracker for `total` jobs.
    pub fn new(total: usize) -> Self {
        Self { done: AtomicUsize::new(0), total: AtomicUsize::new(total) }
    }

    /// Record one completed job; returns the new completion count.
    pub fn tick(&self) -> usize {
        self.done.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// (done, total).
    pub fn snapshot(&self) -> (usize, usize) {
        (self.done.load(Ordering::Relaxed), self.total.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<usize> = (0..1000).collect();
        let results = parallel_map(jobs, 8, |&x| x * 2);
        assert_eq!(results, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_works() {
        let results = parallel_map(vec![1, 2, 3], 1, |&x| x + 1);
        assert_eq!(results, vec![2, 3, 4]);
    }

    #[test]
    fn empty_jobs_ok() {
        let results: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |&x| x);
        assert!(results.is_empty());
    }

    #[test]
    fn uneven_job_costs_balance() {
        // Mix of cheap and expensive jobs; correctness, not timing, checked.
        let jobs: Vec<u64> = (0..64).map(|i| if i % 7 == 0 { 200_000 } else { 10 }).collect();
        let results = parallel_map(jobs.clone(), 4, |&n| (0..n).sum::<u64>());
        for (job, result) in jobs.iter().zip(&results) {
            assert_eq!(*result, job * (job - 1) / 2);
        }
    }

    #[test]
    fn progress_counts() {
        let progress = Progress::new(10);
        assert_eq!(progress.snapshot(), (0, 10));
        assert_eq!(progress.tick(), 1);
        assert_eq!(progress.tick(), 2);
        assert_eq!(progress.snapshot(), (2, 10));
    }

    #[test]
    fn default_workers_positive() {
        assert!(default_workers() >= 1);
    }
}
