//! The DSE coordinator: leader/worker orchestration of the paper's
//! evaluation campaigns (the framework's L3 contribution).
//!
//! The campaign pipeline now lives in [`crate::explore::Explorer`] — one
//! streaming, fallible entry point shared by the CLI, the report
//! generator, the benches, and the examples. This module keeps the worker
//! pool ([`pool`]) and the legacy [`Coordinator`] façade, whose
//! `campaign`/`explore_model` methods are thin deprecated shims over the
//! explorer (the aggregate types are re-exported for source
//! compatibility).

pub mod pool;

pub use pool::{default_workers, parallel_map};

// Source compatibility: these aggregates moved to `crate::explore`.
pub use crate::explore::{CampaignStats, EvalDatabase, ModelSpace};

use crate::arch::SweepSpec;
use crate::dnn::{Dataset, Model};
use crate::dse::Evaluation;
use crate::explore::Explorer;

/// Coordinator configuration (legacy façade over [`Explorer`]).
#[derive(Debug, Clone)]
pub struct Coordinator {
    /// Worker thread count.
    pub workers: usize,
    /// Synthesis-noise seed.
    pub seed: u64,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self { workers: default_workers(), seed: 0x9ADA }
    }
}

impl Coordinator {
    /// New coordinator with an explicit worker count and seed.
    pub fn new(workers: usize, seed: u64) -> Self {
        Self { workers: workers.max(1), seed }
    }

    /// Run the full campaign for one dataset: every design point ×
    /// every paper model for that dataset (Fig. 4 panels).
    ///
    /// # Panics
    /// On a degenerate sweep (empty axis). Use [`Explorer::run`] for the
    /// fallible equivalent.
    ///
    /// # Migration
    ///
    /// Move the constructor arguments into the builder; the result is
    /// bit-identical and degenerate sweeps become a typed error instead
    /// of a panic:
    ///
    /// ```
    /// use qadam::arch::SweepSpec;
    /// use qadam::dnn::Dataset;
    /// use qadam::explore::Explorer;
    ///
    /// // Before: Coordinator::new(4, 7).campaign(&spec, Dataset::Cifar10)
    /// let db = Explorer::over(SweepSpec::tiny())
    ///     .dataset(Dataset::Cifar10)
    ///     .workers(4)
    ///     .seed(7)
    ///     .run()?;
    /// # assert_eq!(db.spaces.len(), 3);
    /// # Ok::<(), qadam::Error>(())
    /// ```
    #[deprecated(
        since = "0.2.0",
        note = "use `Explorer::over(spec).dataset(dataset).workers(n).seed(s).run()`"
    )]
    pub fn campaign(&self, spec: &SweepSpec, dataset: Dataset) -> EvalDatabase {
        Explorer::over(spec.clone())
            .dataset(dataset)
            .workers(self.workers)
            .seed(self.seed)
            .run()
            .expect("legacy campaign requires a non-degenerate sweep")
    }

    /// Evaluate one sweep against one model in parallel (order-preserving).
    ///
    /// # Panics
    /// On a degenerate sweep (empty axis). Use [`Explorer::run`] for the
    /// fallible equivalent.
    ///
    /// # Migration
    ///
    /// The evaluation vector lives in the database's single model space;
    /// order and every metric bit are unchanged:
    ///
    /// ```
    /// use qadam::arch::SweepSpec;
    /// use qadam::dnn::{model_for, Dataset, ModelKind};
    /// use qadam::explore::Explorer;
    ///
    /// let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
    /// // Before: Coordinator::new(4, 7).explore_model(&spec, &model)
    /// let db = Explorer::over(SweepSpec::tiny())
    ///     .model(model)
    ///     .workers(4)
    ///     .seed(7)
    ///     .run()?;
    /// let evals = &db.spaces[0].evals;
    /// # assert_eq!(evals.len(), SweepSpec::tiny().len());
    /// # Ok::<(), qadam::Error>(())
    /// ```
    #[deprecated(
        since = "0.2.0",
        note = "use `Explorer::over(spec).model(model).workers(n).seed(s).run()`"
    )]
    pub fn explore_model(&self, spec: &SweepSpec, model: &Model) -> Vec<Evaluation> {
        let db = Explorer::over(spec.clone())
            .model(model.clone())
            .workers(self.workers)
            .seed(self.seed)
            .run()
            .expect("legacy explore_model requires a non-degenerate sweep");
        db.spaces.into_iter().next().map(|space| space.evals).unwrap_or_default()
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::dse;
    use crate::quant::PeType;

    #[test]
    fn legacy_campaign_covers_models_and_space() {
        let coordinator = Coordinator::new(2, 7);
        let spec = SweepSpec::tiny();
        let db = coordinator.campaign(&spec, Dataset::Cifar10);
        assert_eq!(db.spaces.len(), 3); // VGG-16, ResNet-20, ResNet-56
        for space in &db.spaces {
            assert_eq!(space.evals.len(), spec.len());
        }
        assert_eq!(db.stats.evaluations, spec.len() * 3);
        assert!(db.stats.evals_per_sec() > 0.0);
    }

    #[test]
    fn legacy_shims_match_explorer_bit_for_bit() {
        let spec = SweepSpec::tiny();
        let coordinator = Coordinator::new(4, 7);
        let legacy = coordinator.campaign(&spec, Dataset::Cifar10);
        let new = Explorer::over(spec.clone())
            .dataset(Dataset::Cifar10)
            .workers(4)
            .seed(7)
            .run()
            .unwrap();
        assert_eq!(legacy.spaces.len(), new.spaces.len());
        for (a, b) in legacy.spaces.iter().zip(&new.spaces) {
            assert_eq!(a.model_name, b.model_name);
            for (x, y) in a.evals.iter().zip(&b.evals) {
                assert_eq!(x.config.id(), y.config.id());
                assert_eq!(x.perf_per_area, y.perf_per_area);
                assert_eq!(x.energy_uj, y.energy_uj);
            }
        }
    }

    #[test]
    fn legacy_explore_model_preserves_order() {
        let spec = SweepSpec::tiny();
        let model = crate::dnn::model_for(crate::dnn::ModelKind::ResNet20, Dataset::Cifar10);
        let serial: Vec<dse::Evaluation> =
            spec.iter().map(|c| dse::evaluate(&c, &model, 7)).collect();
        let parallel = Coordinator::new(4, 7).explore_model(&spec, &model);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.config.id(), b.config.id());
            assert_eq!(a.perf_per_area, b.perf_per_area);
            assert_eq!(a.energy_uj, b.energy_uj);
        }
    }

    #[test]
    fn geomean_headline_sane() {
        let db = Explorer::over(SweepSpec::default())
            .dataset(Dataset::Cifar10)
            .workers(2)
            .seed(7)
            .run()
            .unwrap();
        let headline = db.headline_geomean().unwrap();
        let light1 = headline.iter().find(|(pe, _, _)| *pe == PeType::LightPe1).unwrap();
        assert!(light1.1 > 1.5, "LightPE-1 geomean perf/area {}", light1.1);
        assert!(light1.2 > 1.5, "LightPE-1 geomean energy {}", light1.2);
        let int16 = headline.iter().find(|(pe, _, _)| *pe == PeType::Int16).unwrap();
        assert!((int16.1 - 1.0).abs() < 1e-9, "INT16 baseline must be 1.0");
    }
}
