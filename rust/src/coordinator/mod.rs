//! Worker-pool substrate for parallel evaluation (the framework's L3
//! contribution).
//!
//! The campaign pipeline lives in [`crate::explore::Explorer`] — one
//! streaming, fallible entry point shared by the CLI, the report
//! generator, the benches, and the examples. This module keeps the
//! self-balancing worker pool ([`pool`]) underneath it. The legacy
//! `Coordinator` façade and its `campaign`/`explore_model` shims
//! (deprecated since the Explorer landed) have been removed; the
//! campaign aggregates they produced are re-exported from
//! [`crate::explore`] for source compatibility.
//!
//! # Migration
//!
//! ```
//! use qadam::arch::SweepSpec;
//! use qadam::dnn::Dataset;
//! use qadam::explore::Explorer;
//!
//! // Before: Coordinator::new(4, 7).campaign(&spec, Dataset::Cifar10)
//! let db = Explorer::over(SweepSpec::tiny())
//!     .dataset(Dataset::Cifar10)
//!     .workers(4)
//!     .seed(7)
//!     .run()?;
//! # assert_eq!(db.spaces.len(), 3);
//! // Before: Coordinator::new(4, 7).explore_model(&spec, &model) —
//! // build with `.model(model)` instead; the evaluation vector is
//! // `db.spaces[0].evals`, same order, bit-identical metrics.
//! # Ok::<(), qadam::Error>(())
//! ```

pub mod pool;

pub use pool::{default_workers, parallel_map};

// Source compatibility: these aggregates moved to `crate::explore`.
pub use crate::explore::{CampaignStats, EvalDatabase, ModelSpace};

#[cfg(test)]
mod tests {
    use crate::arch::SweepSpec;
    use crate::dnn::Dataset;
    use crate::explore::Explorer;
    use crate::quant::PeType;

    #[test]
    fn geomean_headline_sane() {
        let db = Explorer::over(SweepSpec::default())
            .dataset(Dataset::Cifar10)
            .workers(2)
            .seed(7)
            .run()
            .unwrap();
        let headline = db.headline_geomean().unwrap();
        let light1 = headline.iter().find(|(pe, _, _)| *pe == PeType::LightPe1).unwrap();
        assert!(light1.1 > 1.5, "LightPE-1 geomean perf/area {}", light1.1);
        assert!(light1.2 > 1.5, "LightPE-1 geomean energy {}", light1.2);
        let int16 = headline.iter().find(|(pe, _, _)| *pe == PeType::Int16).unwrap();
        assert!((int16.1 - 1.0).abs() < 1e-9, "INT16 baseline must be 1.0");
    }
}
