//! The DSE coordinator: leader/worker orchestration of the paper's
//! evaluation campaigns (the framework's L3 contribution).
//!
//! The leader shards the design space across a worker pool ([`pool`]),
//! amortizes synthesis per design point across the dataset's model set
//! (synthesize once, map every model), aggregates results into an
//! [`EvalDatabase`], and exposes the campaign products the figures need:
//! normalized spaces, headline ratios, and Pareto fronts. Metrics cover
//! throughput (design points/s) for the §Perf pass.

pub mod pool;

pub use pool::{default_workers, parallel_map};

use std::time::Instant;

use crate::arch::SweepSpec;
use crate::dnn::{models_for, Dataset, Model};
use crate::dse::{self, Evaluation};
use crate::quant::PeType;
use crate::synth::synthesize;

/// All evaluations for one (model, dataset) pair.
#[derive(Debug, Clone)]
pub struct ModelSpace {
    pub model_name: String,
    pub dataset: Dataset,
    pub evals: Vec<Evaluation>,
}

/// Campaign results across a dataset's model set.
#[derive(Debug, Clone)]
pub struct EvalDatabase {
    pub dataset: Dataset,
    pub spaces: Vec<ModelSpace>,
    pub stats: CampaignStats,
}

/// Coordinator throughput metrics.
#[derive(Debug, Clone, Copy)]
pub struct CampaignStats {
    pub design_points: usize,
    pub evaluations: usize,
    pub wall_seconds: f64,
    pub workers: usize,
}

impl CampaignStats {
    /// Evaluations per second (the §Perf headline for L3).
    pub fn evals_per_sec(&self) -> f64 {
        self.evaluations as f64 / self.wall_seconds.max(1e-9)
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct Coordinator {
    pub workers: usize,
    pub seed: u64,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self { workers: default_workers(), seed: 0x9ADA }
    }
}

impl Coordinator {
    /// New coordinator with an explicit worker count and seed.
    pub fn new(workers: usize, seed: u64) -> Self {
        Self { workers: workers.max(1), seed }
    }

    /// Run the full campaign for one dataset: every design point ×
    /// every paper model for that dataset (Fig. 4 panels).
    ///
    /// Work unit = one design point: synthesis runs once, then every model
    /// maps against the same report — the paper's framework evaluates "a
    /// range of hardware designs and DNN configurations at the same time".
    pub fn campaign(&self, spec: &SweepSpec, dataset: Dataset) -> EvalDatabase {
        let models = models_for(dataset);
        let configs = spec.enumerate();
        let started = Instant::now();
        let seed = self.seed;
        let per_config: Vec<Vec<Evaluation>> =
            parallel_map(configs, self.workers, |config| {
                let synth = synthesize(config, seed);
                models.iter().map(|m| dse::evaluate_with_synth(&synth, m)).collect()
            });
        let wall_seconds = started.elapsed().as_secs_f64();
        let design_points = per_config.len();
        // Transpose: per-config × per-model → per-model spaces.
        let mut spaces: Vec<ModelSpace> = models
            .iter()
            .map(|m| ModelSpace {
                model_name: m.name.clone(),
                dataset,
                evals: Vec::with_capacity(design_points),
            })
            .collect();
        for config_evals in per_config {
            for (space, eval) in spaces.iter_mut().zip(config_evals) {
                space.evals.push(eval);
            }
        }
        let evaluations = design_points * models.len();
        EvalDatabase {
            dataset,
            spaces,
            stats: CampaignStats {
                design_points,
                evaluations,
                wall_seconds,
                workers: self.workers,
            },
        }
    }

    /// Evaluate one sweep against one model in parallel (order-preserving).
    pub fn explore_model(&self, spec: &SweepSpec, model: &Model) -> Vec<Evaluation> {
        let configs = spec.enumerate();
        let seed = self.seed;
        parallel_map(configs, self.workers, |config| dse::evaluate(config, model, seed))
    }
}

impl EvalDatabase {
    /// Headline ratios per model (Fig. 4 summary): the geometric-mean
    /// across models is the paper's "on average across all workloads".
    pub fn headline_per_model(&self) -> Vec<(String, Vec<(PeType, f64, f64)>)> {
        self.spaces
            .iter()
            .map(|s| (s.model_name.clone(), dse::headline_ratios(&s.evals)))
            .collect()
    }

    /// Geometric-mean headline ratios across this dataset's models:
    /// (pe, perf/area gain, energy gain).
    pub fn headline_geomean(&self) -> Vec<(PeType, f64, f64)> {
        let per_model = self.headline_per_model();
        PeType::ALL
            .iter()
            .filter(|&&pe| {
                // Skip PE types absent from the explored space.
                per_model
                    .iter()
                    .any(|(_, rs)| rs.iter().any(|(p, _, _)| *p == pe))
            })
            .map(|&pe| {
                let ppa: Vec<f64> = per_model
                    .iter()
                    .filter_map(|(_, rs)| {
                        rs.iter().find(|(p, _, _)| *p == pe).map(|(_, a, _)| *a)
                    })
                    .collect();
                let energy: Vec<f64> = per_model
                    .iter()
                    .filter_map(|(_, rs)| {
                        rs.iter().find(|(p, _, _)| *p == pe).map(|(_, _, e)| *e)
                    })
                    .collect();
                (
                    pe,
                    crate::util::stats::geomean(&ppa),
                    crate::util::stats::geomean(&energy),
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn campaign_covers_models_and_space() {
        let coordinator = Coordinator::new(2, 7);
        let spec = SweepSpec::tiny();
        let db = coordinator.campaign(&spec, Dataset::Cifar10);
        assert_eq!(db.spaces.len(), 3); // VGG-16, ResNet-20, ResNet-56
        for space in &db.spaces {
            assert_eq!(space.evals.len(), spec.len());
        }
        assert_eq!(db.stats.evaluations, spec.len() * 3);
        assert!(db.stats.evals_per_sec() > 0.0);
    }

    #[test]
    fn parallel_matches_serial() {
        let spec = SweepSpec::tiny();
        let model = crate::dnn::model_for(crate::dnn::ModelKind::ResNet20, Dataset::Cifar10);
        let serial = dse::explore(&spec, &model, 7);
        let parallel = Coordinator::new(4, 7).explore_model(&spec, &model);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.config.id(), b.config.id());
            assert_eq!(a.perf_per_area, b.perf_per_area);
            assert_eq!(a.energy_uj, b.energy_uj);
        }
    }

    #[test]
    fn geomean_headline_sane() {
        let db = Coordinator::new(2, 7).campaign(&SweepSpec::default(), Dataset::Cifar10);
        let headline = db.headline_geomean();
        let light1 = headline.iter().find(|(pe, _, _)| *pe == PeType::LightPe1).unwrap();
        assert!(light1.1 > 1.5, "LightPE-1 geomean perf/area {}", light1.1);
        assert!(light1.2 > 1.5, "LightPE-1 geomean energy {}", light1.2);
        let int16 = headline.iter().find(|(pe, _, _)| *pe == PeType::Int16).unwrap();
        assert!((int16.1 - 1.0).abs() < 1e-9, "INT16 baseline must be 1.0");
    }
}
