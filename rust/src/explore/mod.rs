//! Unified design-space exploration API (§IV-A) — the single entry point
//! every DSE consumer (CLI, report generator, benches, examples) goes
//! through.
//!
//! [`Explorer`] is a builder over a [`DesignSpace`] — the joint
//! hardware × model cross-product (a bare [`SweepSpec`](crate::arch::SweepSpec) converts into a
//! hardware-only space). Pick the base model set (or a whole dataset's
//! paper models), optionally sweep [`ModelAxes`] (width/depth
//! multipliers lowered per variant by [`crate::dnn::scale_model`]), a
//! worker count, a seed, and optionally a round-robin shard of the
//! space, then either
//!
//! * [`Explorer::run`] — evaluate everything into an [`EvalDatabase`]
//!   (one [`ModelSpace`] per scaled-model variant), or
//! * [`Explorer::stream`] — consume [`PointResult`]s incrementally, in
//!   joint-index order, while workers are still evaluating the rest.
//!
//! Either way the pipeline is the same: joint points are decoded lazily
//! from the space's mixed-radix index (no materialization; model
//! variants are the outermost digit, so hardware-only campaigns walk
//! exactly the indices they always have), one
//! [`SynthReport`](crate::synth::SynthReport) is amortized per joint
//! point across the variant's model set (synthesize once, map every
//! model), and evaluation is spread over a self-balancing worker pool.
//! Results are deterministic for a fixed seed regardless of worker
//! count.
//!
//! Campaigns are also *persistent* ([`persist`]): [`Explorer::cache`]
//! consults a content-addressed [`PointCache`] before synthesizing,
//! [`Explorer::checkpoint`] journals every delivered point so a killed
//! campaign resumes from the last flushed one, and the resulting
//! [`EvalDatabase`] saves/loads as schema-versioned canonical JSON
//! (`qadam dse --save/--load/--resume`).
//!
//! Campaigns compose with the Pareto engine ([`crate::pareto`]):
//! [`Explorer::strategy`] walks a selected subspace instead of the full
//! cross-product ([`RandomSample`](crate::pareto::RandomSample),
//! [`SuccessiveHalving`](crate::pareto::SuccessiveHalving)), and
//! [`Explorer::frontier`] maintains per-model streaming Pareto fronts
//! that are observable live while workers are still evaluating.
//!
//! ```
//! use qadam::arch::SweepSpec;
//! use qadam::dnn::Dataset;
//! use qadam::explore::Explorer;
//!
//! // A small but real campaign: every point of the tiny sweep against
//! // CIFAR-10's paper model set.
//! let db = Explorer::over(SweepSpec::tiny())
//!     .dataset(Dataset::Cifar10)
//!     .workers(2)
//!     .seed(7)
//!     .run()?;
//! assert_eq!(db.spaces.len(), 3); // VGG-16, ResNet-20, ResNet-56
//! for (pe, ppa, energy) in db.headline_geomean()? {
//!     println!("{pe}: {ppa:.2}x perf/area, {energy:.2}x less energy");
//! }
//! # Ok::<(), qadam::Error>(())
//! ```

pub mod db;
pub mod persist;
pub mod qdb;

pub use db::{CampaignStats, EvalDatabase, ModelSpace};
pub use persist::{point_key, point_key_with, PointCache, BASE_SCHEMA_VERSION, SCHEMA_VERSION};
pub use qdb::{
    inspect_qdb, QdbInfo, QdbPlan, QdbSpacePlan, QdbWriter, QDB_MAGIC, QDB_SCHEMA_VERSION,
};

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use crate::arch::{AcceleratorConfig, DesignSpace, ModelAxes};
use crate::coordinator::pool::default_workers;
use crate::dnn::{lower_workload, models_for, Dataset, Model};
use crate::dse::{self, Evaluation};
use crate::error::{Error, Result};
use crate::obs::{TraceEvent, TraceSink};
use crate::pareto::{
    CampaignFrontier, FrontierBinding, InsertOutcome, RoundReport, Selection, Strategy,
    StrategyContext,
};
use crate::synth::synthesize;

/// One fully evaluated joint design point, streamed as soon as it is
/// ready.
#[derive(Debug, Clone)]
pub struct PointResult {
    /// Index of this point in the joint space's cross-product order
    /// (equal to the hardware sweep index for hardware-only campaigns;
    /// decode the model variant with
    /// [`DesignSpace::variant_of`]).
    pub index: usize,
    /// The decoded hardware design point.
    pub config: AcceleratorConfig,
    /// One evaluation per base model — scaled to this point's variant —
    /// in the explorer's model order.
    pub evals: Vec<Evaluation>,
}

/// Builder for a design-space exploration campaign.
#[derive(Debug, Clone)]
pub struct Explorer {
    space: DesignSpace,
    models: Vec<Model>,
    dataset: Option<Dataset>,
    workers: usize,
    seed: u64,
    shard: (usize, usize),
    cache: Option<Arc<Mutex<PointCache>>>,
    checkpoint: Option<(PathBuf, usize)>,
    strategy: Option<Arc<dyn Strategy>>,
    frontier: Option<Arc<Mutex<CampaignFrontier>>>,
    campaign_fp: Option<u64>,
    trace: Option<Arc<dyn TraceSink>>,
}

impl Explorer {
    /// Start a campaign over a design space — a [`SweepSpec`](crate::arch::SweepSpec)
    /// (hardware axes only) or a full [`DesignSpace`] (joint hardware × model).
    /// Defaults: no models (set via [`Self::models`], [`Self::model`],
    /// or [`Self::dataset`]), all cores minus one, the coordinator's
    /// historical seed, the whole space.
    pub fn over(space: impl Into<DesignSpace>) -> Self {
        Self {
            space: space.into(),
            models: Vec::new(),
            dataset: None,
            workers: default_workers(),
            seed: 0x9ADA,
            shard: (0, 1),
            cache: None,
            checkpoint: None,
            strategy: None,
            frontier: None,
            campaign_fp: None,
            trace: None,
        }
    }

    /// Sweep model-hyperparameter axes jointly with the hardware: every
    /// base model in the workload is lowered per (width, depth) variant
    /// by [`crate::dnn::scale_model`], and variants participate in
    /// strategy selection, sharding, checkpointing, and the streamed
    /// frontier exactly like hardware axes. Replaces any axes already
    /// carried by the space handed to [`Self::over`].
    pub fn model_axes(mut self, axes: ModelAxes) -> Self {
        self.space.model = axes;
        self
    }

    /// The joint design space this campaign walks.
    pub fn space(&self) -> &DesignSpace {
        &self.space
    }

    /// Explore against an explicit model set (replaces any prior set).
    pub fn models(mut self, models: Vec<Model>) -> Self {
        self.models = models;
        self
    }

    /// Add a single model to the workload set.
    pub fn model(mut self, model: Model) -> Self {
        self.models.push(model);
        self
    }

    /// Explore against a dataset's full paper model set (Fig. 4 panels);
    /// replaces any prior model set and labels the database.
    pub fn dataset(mut self, dataset: Dataset) -> Self {
        self.dataset = Some(dataset);
        self.models = models_for(dataset);
        self
    }

    /// Worker thread count (`0` = cores minus one).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = if workers == 0 { default_workers() } else { workers };
        self
    }

    /// Seed for the synthesis noise model (determinism knob).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Restrict to the round-robin shard `shard` of `num_shards` (the
    /// leader/worker split; composes with
    /// [`SweepSpec::shard_iter`](crate::arch::SweepSpec::shard_iter)).
    pub fn shard(mut self, shard: usize, num_shards: usize) -> Self {
        self.shard = (shard, num_shards);
        self
    }

    /// Consult (and fill) a content-addressed [`PointCache`] instead of
    /// re-synthesizing design points already evaluated under the same
    /// `(config, seed, model set)` key — see [`persist::point_key`].
    /// Cached results are bit-identical to recomputation, so warm-cache
    /// campaigns produce exactly the same database as cold ones. The
    /// cache is shared: clone the `Arc` across concurrent campaigns over
    /// overlapping spaces to amortize their synthesis work.
    pub fn cache(mut self, cache: Arc<Mutex<PointCache>>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Journal every delivered design point to `path`, flushing every
    /// `every_n` points (`0` is treated as `1`). If the journal already
    /// exists it must match this campaign (sweep fingerprint, seed,
    /// shard, model set — else [`Error::InvalidConfig`]); its flushed
    /// prefix is replayed without re-evaluation and the campaign resumes
    /// from the first unjournaled point, yielding a byte-identical
    /// database to an uninterrupted run.
    pub fn checkpoint(mut self, path: impl Into<PathBuf>, every_n: usize) -> Self {
        self.checkpoint = Some((path.into(), every_n.max(1)));
        self
    }

    /// Walk only the design points a [`Strategy`] selects instead of the
    /// full cross-product — e.g.
    /// [`RandomSample`](crate::pareto::RandomSample)`{n, seed}` touches
    /// exactly `n` points of a million-point space. Selection happens
    /// once, up front, and is deterministic in the strategy's own
    /// parameters; the checkpoint journal pins the strategy's
    /// [`descriptor`](Strategy::descriptor) so a resume under a
    /// different strategy is rejected. Composes with [`Self::shard`]
    /// (the strategy selects within this process's shard).
    pub fn strategy(mut self, strategy: impl Strategy + 'static) -> Self {
        self.strategy = Some(Arc::new(strategy));
        self
    }

    /// Maintain live per-model Pareto fronts over (perf/area ↑, energy ↓)
    /// while the campaign streams: every delivered point is offered to
    /// the shared [`CampaignFrontier`], so another thread can inspect
    /// the frontier mid-campaign, and only O(front) of a huge sweep is
    /// retained when the sink discards the rest. The frontier is bound
    /// to this campaign's identity (sweep fingerprint, seed, shard,
    /// strategy, model set) on first use — attaching it to a different
    /// campaign is [`Error::InvalidConfig`] — and observation is
    /// position-cursored, so checkpoint replays and reattached frontiers
    /// end up exactly as an uninterrupted campaign would, never
    /// double-counted.
    pub fn frontier(mut self, frontier: Arc<Mutex<CampaignFrontier>>) -> Self {
        self.frontier = Some(frontier);
        self
    }

    /// Pin a campaign-spec fingerprint (FNV-1a of the QSL canonical
    /// identity — see
    /// [`ResolvedCampaign::fingerprint`](crate::spec::ResolvedCampaign::fingerprint))
    /// into this campaign's checkpoint-journal manifest. Resuming a
    /// journal whose fingerprint differs — the spec was edited, or one
    /// side ran without a spec — is rejected with
    /// [`Error::InvalidConfig`]. Campaigns built through
    /// [`crate::spec::ResolvedCampaign`] (both `qadam run` and
    /// `qadam dse`) always set this; direct `Explorer` users may not,
    /// and two fingerprint-less campaigns resume freely as before.
    pub fn campaign_fingerprint(mut self, fingerprint: u64) -> Self {
        self.campaign_fp = Some(fingerprint);
        self
    }

    /// Record the campaign's deterministic event stream into `sink`
    /// (see [`crate::obs`]): campaign begin/end, the strategy funnel,
    /// and — per delivered point, in delivery order — dispatch, cache
    /// hit/miss, frontier insertion outcomes, delivery, and the
    /// journal's logical flush schedule. Every emission site is on
    /// single-threaded code (selection, replay, the ordered delivery
    /// loop), so the stream is byte-identical at any worker count and
    /// across kill/resume. An attached sink also enables per-point
    /// evaluation timing, forwarded to the sink out-of-band for the
    /// `qadam.timing` sidecar — never into the trace itself.
    pub fn trace_sink(mut self, sink: Arc<dyn TraceSink>) -> Self {
        self.trace = Some(sink);
        self
    }

    fn validate(&self) -> Result<()> {
        // Name the offending axis (hardware or model) so a degenerate
        // joint space never hides behind a generic message.
        self.space.ensure_nonempty()?;
        if self.models.is_empty() {
            return Err(Error::InvalidConfig(
                "no models to evaluate: call .models(), .model(), or .dataset()".into(),
            ));
        }
        let (shard, num_shards) = self.shard;
        if num_shards == 0 || shard >= num_shards {
            return Err(Error::InvalidConfig(format!(
                "shard {shard} out of range for {num_shards} shards"
            )));
        }
        Ok(())
    }

    /// Number of joint design points in this explorer's shard of the
    /// space, *before* any [`Self::strategy`] selection (a strategy can
    /// only shrink the walk; [`CampaignStats::design_points`] reports
    /// what a campaign actually evaluated).
    pub fn design_points(&self) -> usize {
        let (shard, num_shards) = self.shard;
        let len = self.space.len();
        if num_shards == 0 || shard >= len {
            0
        } else {
            (len - shard).div_ceil(num_shards)
        }
    }

    /// The workload lowered per model variant: `variant_models[v][m]` is
    /// base model `m` scaled by variant `v` (the base model itself for
    /// the identity variant, so hardware-only campaigns see their exact
    /// input models). Built once per campaign — never per point — by
    /// the shared [`lower_workload`] transform, the same lowering the
    /// halving strategy scores against.
    fn variant_models(&self) -> Vec<Vec<Model>> {
        lower_workload(&self.space.model, &self.models)
    }

    /// Evaluate every selected design point and aggregate per
    /// scaled-model spaces — the campaign product the figures consume.
    /// Spaces are variant-major (all base models of variant 0, then of
    /// variant 1, …), matching the joint walk order; hardware-only
    /// campaigns produce exactly the per-base-model spaces they always
    /// have.
    pub fn run(&self) -> Result<EvalDatabase> {
        self.validate()?;
        let axes = &self.space.model;
        // A strategy may select a tiny fraction of a huge space — and a
        // joint walk spreads points across variants — so only pre-size
        // the spaces for exhaustive hardware-only walks.
        let capacity = if self.strategy.is_some() || axes.len() > 1 {
            0
        } else {
            self.design_points()
        };
        // Only the names are needed here (scaling preserves the
        // dataset); the full lowering happens once, inside stream().
        let mut spaces: Vec<ModelSpace> = (0..axes.len())
            .flat_map(|v| {
                // v < axes.len(), so the index always decodes.
                #[allow(clippy::expect_used)]
                let variant = axes.variant(v).expect("variant index in range");
                self.models.iter().map(move |m| ModelSpace {
                    model_name: crate::dnn::variant_model_name(
                        &m.name,
                        variant.width,
                        variant.depth,
                    ),
                    dataset: m.dataset,
                    evals: Vec::with_capacity(capacity),
                })
            })
            .collect();
        let model_count = self.models.len();
        let space = &self.space;
        let stats = self.stream(|point| {
            let variant = space.variant_index(point.index);
            let base = variant * model_count;
            for (offset, eval) in point.evals.into_iter().enumerate() {
                spaces[base + offset].evals.push(eval);
            }
        })?;
        let dataset = self.dataset.unwrap_or(self.models[0].dataset);
        // Coverage, not intent: a strategy whose selection degraded to
        // the whole space (e.g. `random:N` with N ≥ the space) produced a
        // complete database, which must stay normalizable.
        let strategy = if stats.design_points == self.design_points() {
            "exhaustive".to_string()
        } else {
            self.strategy_descriptor()
        };
        Ok(EvalDatabase { dataset, shard: self.shard, strategy, spaces, stats })
    }

    /// The campaign's strategy identity (`"exhaustive"` when none is
    /// set) — the single source for the journal manifest and the
    /// frontier binding, which must agree exactly for resumes to work.
    fn strategy_descriptor(&self) -> String {
        self.strategy
            .as_ref()
            .map_or_else(|| "exhaustive".to_string(), |s| s.descriptor())
    }

    /// The identity pinned in checkpoint journal headers; only valid
    /// after [`Self::validate`] (needs a non-empty model set). `total`
    /// is the strategy-selected point count this campaign delivers.
    /// The fingerprint covers the *joint* space (model axes included),
    /// and non-trivial axes are additionally pinned verbatim so the
    /// mismatch error can say what changed.
    fn manifest(&self, total: usize) -> persist::CampaignManifest {
        persist::CampaignManifest {
            spec_fingerprint: self.space.fingerprint(),
            seed: self.seed,
            shard: self.shard.0,
            num_shards: self.shard.1,
            total,
            dataset: self.dataset.unwrap_or(self.models[0].dataset).name().to_string(),
            models: self.models.iter().map(|m| m.name.clone()).collect(),
            strategy: self.strategy_descriptor(),
            model_axes: self.space.model.clone(),
            campaign_fp: self.campaign_fp,
        }
    }

    /// Evaluate the (strategy-selected subset of the) space, delivering
    /// each design point to `sink` in cross-product order as soon as it
    /// (and all earlier points) is ready. With a [`Self::strategy`] the
    /// walk visits only the selected positions, still in ascending index
    /// order. Memory is bounded: workers never run more than a small
    /// window ahead of the sink, so at most O(workers) results are ever
    /// buffered and nothing is retained after the sink returns —
    /// million-point campaigns can stream to disk, sockets, or running
    /// aggregations. (A [`Self::checkpoint`] resume is the exception: the
    /// journaled prefix is loaded eagerly before replay.)
    pub fn stream(&self, mut sink: impl FnMut(PointResult)) -> Result<CampaignStats> {
        self.validate()?;
        let (shard, num_shards) = self.shard;
        let space_positions = self.design_points();
        // The workload lowered once per model variant (the base models
        // themselves for a hardware-only campaign).
        let variant_models = self.variant_models();
        // Strategy selection: which shard positions this campaign visits.
        // Runs once, up front, so the walk itself stays lazy. The
        // observer collects per-round prune counts for the trace;
        // `select_observed` is contractually identical to `select`, so
        // traced and untraced campaigns pick the same points.
        let mut strategy_rounds: Vec<RoundReport> = Vec::new();
        let selection = match &self.strategy {
            None => Selection::All,
            Some(strategy) => {
                let ctx = StrategyContext {
                    space: &self.space,
                    models: &self.models,
                    seed: self.seed,
                    shard: self.shard,
                    positions: space_positions,
                };
                let selected =
                    strategy.select_observed(&ctx, &mut |report| strategy_rounds.push(report))?;
                selected.validate(space_positions)?;
                selected
            }
        };
        let total = selection.len(space_positions);
        let subset: Option<&[usize]> = match &selection {
            Selection::All => None,
            Selection::Subset(positions) => Some(positions),
        };
        // Delivery position -> cross-product index, through the strategy
        // selection; shared by the workers and the journal validation.
        let index_for = move |pos: usize| {
            let position = subset.map_or(pos, |positions| positions[pos]);
            shard + position * num_shards
        };
        let started = Instant::now();
        // Trace prologue: campaign identity, then the strategy funnel.
        // Everything the trace records is emitted from single-threaded
        // code, so the event stream is deterministic (DESIGN.md §11).
        let flush_every = self.checkpoint.as_ref().map(|(_, every_n)| (*every_n).max(1));
        let mut cache_counts = (0u64, 0u64);
        if let Some(trace) = self.trace.as_deref() {
            trace.record(TraceEvent::CampaignBegin {
                fingerprint: self.campaign_fp,
                space_fingerprint: self.space.fingerprint(),
                seed: self.seed,
                shard,
                num_shards,
                strategy: self.strategy_descriptor(),
                total,
                models: self.models.len(),
                variants: variant_models.len(),
            });
            for report in &strategy_rounds {
                trace.record(TraceEvent::StrategyRound {
                    round: report.round,
                    entered: report.entered,
                    kept: report.kept,
                });
            }
            if self.strategy.is_some() {
                trace.record(TraceEvent::StrategySelect {
                    descriptor: self.strategy_descriptor(),
                    selected: total,
                    positions: space_positions,
                });
            }
        }
        // Live frontier: bind the campaign identity before any delivery
        // (a frontier bound to a different campaign is rejected here).
        // The fingerprint is the *joint* space's, so fronts from
        // campaigns with different model axes can never merge.
        if let Some(frontier) = &self.frontier {
            let binding = FrontierBinding {
                spec_fingerprint: self.space.fingerprint(),
                seed: self.seed,
                shard: self.shard,
                dataset: self.dataset.unwrap_or(self.models[0].dataset).name().to_string(),
                strategy: self.strategy_descriptor(),
                models: self.models.iter().map(|m| m.name.clone()).collect(),
            };
            lock_shared(frontier).begin(&binding)?;
        }
        // Checkpointing: open (or resume) the journal and replay the
        // flushed prefix through the sink without re-evaluating it.
        let mut journal: Option<persist::JournalWriter> = None;
        let mut start_pos = 0usize;
        if let Some((path, every_n)) = &self.checkpoint {
            let (writer, replayed) =
                persist::JournalWriter::open(path, &self.manifest(total), *every_n, &index_for)?;
            start_pos = replayed.len();
            for (pos, point) in replayed.into_iter().enumerate() {
                // The journal holds bit-exact results, so replayed points
                // also warm the cache and the frontier — a resumed
                // campaign must leave both as complete as an
                // uninterrupted one would. `observe_at` skips positions a
                // reattached frontier already archived, so nothing is
                // double-counted. Cache keys use the point's *scaled*
                // model set, exactly like the live workers below.
                let cache_probe = self.cache.as_ref().map(|cache| {
                    let variant = self.space.variant_index(point.index);
                    let key =
                        persist::point_key(&point.config, self.seed, &variant_models[variant]);
                    let mut shared = lock_shared(cache);
                    // Uncounted membership *before* the store reproduces
                    // the live run's hit/miss for the trace: point keys
                    // are unique within a campaign, so the live outcome
                    // depended only on the cache's campaign-start state.
                    let warm = shared.get(key).is_some();
                    shared.store(key, point.evals.clone());
                    (key, warm)
                });
                let outcomes = match &self.frontier {
                    Some(frontier) => {
                        Some(lock_shared(frontier).observe_at(pos, point.index, &point.evals)?)
                    }
                    None => None,
                };
                if let Some(trace) = self.trace.as_deref() {
                    emit_point_events(
                        trace,
                        pos,
                        point.index,
                        cache_probe,
                        outcomes,
                        None,
                        flush_every,
                        &mut cache_counts,
                    );
                }
                sink(point);
            }
            journal = Some(writer);
        }
        let space = &self.space;
        let variant_models_ref = &variant_models;
        let seed = self.seed;
        let cache = self.cache.as_ref();
        let remaining = total - start_pos;
        let worker_count = self.workers.min(remaining.max(1));
        // Max positions a worker may run ahead of the last delivered one;
        // caps the reorder buffer even when one point is pathologically
        // slower than the rest.
        let window = worker_count * 4;
        let cursor = AtomicUsize::new(start_pos);
        let cursor_ref = &cursor;
        let throttle = Throttle::new(start_pos);
        let throttle_ref = &throttle;
        let stop = AtomicBool::new(false);
        let stop_ref = &stop;
        let index_for_ref = &index_for;
        let mut abort_err: Option<Error> = None;
        // Evaluation timing is only measured when a trace sink will
        // consume it — untraced campaigns skip the clock reads.
        let timed = self.trace.is_some();
        let (tx, rx) = mpsc::channel::<Streamed>();
        std::thread::scope(|scope| {
            for _ in 0..worker_count {
                let tx = tx.clone();
                scope.spawn(move || {
                    // Per-worker scratch for cache-key rendering: reused
                    // across every point this worker evaluates, so a
                    // cached campaign allocates no key buffers in steady
                    // state.
                    let mut key_scratch = String::new();
                    loop {
                        // Claim the next unevaluated position
                        // (self-balancing across uneven per-point costs,
                        // like the pool).
                        let pos = cursor_ref.fetch_add(1, Ordering::Relaxed);
                        if pos >= total {
                            break;
                        }
                        // Throttle: sleep until the sink has caught up to
                        // within `window`. The worker holding the lowest
                        // undelivered position never waits, so progress is
                        // guaranteed.
                        if !throttle_ref.wait_within(pos, window, stop_ref) {
                            return;
                        }
                        let index = index_for_ref(pos);
                        // Shard positions are validated against the space
                        // size before the workers start.
                        #[allow(clippy::expect_used)]
                        let point =
                            space.get(index).expect("shard index within joint cross-product");
                        let models = &variant_models_ref[space.variant_index(index)];
                        let config = point.config;
                        let eval_started = timed.then(Instant::now);
                        let (evals, cache_probe) =
                            evaluate_point(&config, models, seed, cache, &mut key_scratch);
                        let eval_ns =
                            eval_started.map_or(0, |at| at.elapsed().as_nanos() as u64);
                        let result = PointResult { index, config, evals };
                        if tx.send(Streamed { pos, result, cache_probe, eval_ns }).is_err() {
                            break;
                        }
                    }
                });
            }
            drop(tx);
            // Release throttled workers on any receiver exit, including a
            // sink panic — otherwise scope join would hang.
            struct StopGuard<'a> {
                stop: &'a AtomicBool,
                throttle: &'a Throttle,
            }
            impl Drop for StopGuard<'_> {
                fn drop(&mut self) {
                    self.stop.store(true, Ordering::SeqCst);
                    self.throttle.wake_all();
                }
            }
            let _guard = StopGuard { stop: stop_ref, throttle: throttle_ref };
            // Reorder out-of-order completions so the sink observes the
            // deterministic cross-product order.
            let mut pending: BTreeMap<usize, Streamed> = BTreeMap::new();
            let mut next = start_pos;
            let mut batch: Vec<Streamed> = Vec::new();
            'recv: for streamed in rx {
                pending.insert(streamed.pos, streamed);
                // Drain every contiguously-ready position into one batch so
                // the journal sees a single group append (one buffered write
                // per flush boundary) instead of per-point write pairs.
                batch.clear();
                while let Some(streamed) = pending.remove(&(next + batch.len())) {
                    batch.push(streamed);
                }
                if batch.is_empty() {
                    continue;
                }
                if let Some(writer) = journal.as_mut() {
                    if let Err(err) = writer.append_batch(batch.iter().map(|s| &s.result)) {
                        // Abandon the campaign: the guard releases the
                        // workers, and the error surfaces after join.
                        abort_err = Some(err);
                        break 'recv;
                    }
                }
                for Streamed { result: ready, cache_probe, eval_ns, .. } in batch.drain(..) {
                    let outcomes = if let Some(frontier) = &self.frontier {
                        match lock_shared(frontier).observe_at(next, ready.index, &ready.evals) {
                            Ok(outcomes) => Some(outcomes),
                            Err(err) => {
                                abort_err = Some(err);
                                break 'recv;
                            }
                        }
                    } else {
                        None
                    };
                    if let Some(trace) = self.trace.as_deref() {
                        emit_point_events(
                            trace,
                            next,
                            ready.index,
                            cache_probe,
                            outcomes,
                            Some(eval_ns),
                            flush_every,
                            &mut cache_counts,
                        );
                    }
                    sink(ready);
                    next += 1;
                    throttle_ref.advance(next);
                }
            }
            debug_assert!(
                abort_err.is_some() || (pending.is_empty() && batch.is_empty()),
                "all streamed points must be delivered"
            );
        });
        if let Some(err) = abort_err {
            return Err(err);
        }
        if let Some(writer) = journal {
            writer.finish()?;
        }
        // Trace epilogue: the journal's final partial flush (a pure
        // function of (total, every), like the boundary flushes), then
        // end-of-campaign aggregates.
        if let Some(trace) = self.trace.as_deref() {
            if let Some(every) = flush_every {
                if total % every != 0 {
                    trace.record(TraceEvent::JournalFlush { upto: total });
                }
            }
            let fronts = match &self.frontier {
                Some(frontier) => {
                    lock_shared(frontier).models().iter().map(|m| m.front().len()).collect()
                }
                None => Vec::new(),
            };
            trace.record(TraceEvent::CampaignEnd {
                points: total,
                evaluations: total * self.models.len(),
                cache_hits: cache_counts.0,
                cache_misses: cache_counts.1,
                fronts,
            });
        }
        Ok(CampaignStats {
            design_points: total,
            evaluations: total * self.models.len(),
            wall_seconds: started.elapsed().as_secs_f64(),
            workers: self.workers,
        })
    }
}

/// Back-pressure gate between [`Explorer::stream`]'s reorder receiver and
/// its workers: a worker about to run more than `window` positions ahead
/// of the last delivered one *sleeps* on a condvar until the sink catches
/// up (or the campaign stops), instead of the 1 ms `park_timeout` polling
/// loop this replaces — throttled workers now burn zero CPU and wake
/// within one notify, not one timer tick.
///
/// Lost-wakeup freedom is the classic two-flag handshake, under `SeqCst`
/// so the two stores/loads on each side cannot reorder:
///
/// * waiter: `waiters += 1`, then re-check `delivered` (and `stop`)
///   *under the gate lock* before every wait;
/// * notifier: publish `delivered` (or `stop`), then check `waiters`,
///   and when nonzero take the gate lock before `notify_all`.
///
/// Either the notifier sees the waiter registered (and notifies under the
/// lock the waiter holds until it actually blocks), or the waiter's
/// locked re-check sees the new `delivered`/`stop` value and never
/// blocks.
struct Throttle {
    /// Next undelivered position — everything below has reached the sink.
    delivered: AtomicUsize,
    /// Number of workers registered on (or entering) the condvar.
    waiters: AtomicUsize,
    /// Gate serializing the re-check-then-wait against notify.
    gate: Mutex<()>,
    cv: Condvar,
}

impl Throttle {
    fn new(start_pos: usize) -> Self {
        Self {
            delivered: AtomicUsize::new(start_pos),
            waiters: AtomicUsize::new(0),
            gate: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    /// Worker side: block until `pos` is within `window` of the delivery
    /// frontier. Returns `false` when the campaign stopped instead.
    fn wait_within(&self, pos: usize, window: usize, stop: &AtomicBool) -> bool {
        // Uncontended fast path: no lock traffic while the sink keeps up.
        if pos < self.delivered.load(Ordering::SeqCst) + window {
            return true;
        }
        self.waiters.fetch_add(1, Ordering::SeqCst);
        let mut guard = self.gate.lock().unwrap_or_else(PoisonError::into_inner);
        let proceed = loop {
            if stop.load(Ordering::SeqCst) {
                break false;
            }
            if pos < self.delivered.load(Ordering::SeqCst) + window {
                break true;
            }
            guard = self.cv.wait(guard).unwrap_or_else(PoisonError::into_inner);
        };
        drop(guard);
        self.waiters.fetch_sub(1, Ordering::SeqCst);
        proceed
    }

    /// Receiver side: publish a new delivery frontier and wake throttled
    /// workers. The common no-waiter case is a single atomic store plus
    /// one atomic load — no lock.
    fn advance(&self, next: usize) {
        self.delivered.store(next, Ordering::SeqCst);
        self.wake_if_waiting();
    }

    /// Wake every throttled worker (stop path — the caller has already
    /// published the state change the workers must observe).
    fn wake_all(&self) {
        self.wake_if_waiting();
    }

    fn wake_if_waiting(&self) {
        if self.waiters.load(Ordering::SeqCst) > 0 {
            // Taking the gate lock orders this notify after any waiter's
            // locked re-check that missed the published value.
            drop(self.gate.lock().unwrap_or_else(PoisonError::into_inner));
            self.cv.notify_all();
        }
    }
}

/// Evaluate one design point against the model set, consulting the
/// content-addressed cache when present (a hit skips synthesis and
/// mapping entirely; the pipeline's determinism makes hits bit-identical
/// to recomputation). `key_scratch` is the caller's reusable buffer for
/// rendering the cache key — workers thread one per thread so steady-state
/// cache probes allocate nothing.
fn evaluate_point(
    config: &AcceleratorConfig,
    models: &[Model],
    seed: u64,
    cache: Option<&Arc<Mutex<PointCache>>>,
    key_scratch: &mut String,
) -> (Vec<Evaluation>, Option<(u64, bool)>) {
    let key = cache.map(|_| persist::point_key_with(config, seed, models, key_scratch));
    if let (Some(cache), Some(key)) = (cache, key) {
        if let Some(hit) = lock_shared(cache).lookup(key) {
            return (hit, Some((key, true)));
        }
    }
    let synth = synthesize(config, seed);
    let evals: Vec<Evaluation> =
        models.iter().map(|m| dse::evaluate_with_synth(&synth, m)).collect();
    if let (Some(cache), Some(key)) = (cache, key) {
        lock_shared(cache).store(key, evals.clone());
    }
    (evals, key.map(|key| (key, false)))
}

/// Worker → receiver channel payload: the evaluated point plus the
/// trace annotations the (single-threaded) delivery loop emits in
/// order — what the cache probe resolved to and how long evaluation
/// took (`0` when untraced; the clock is only read under a sink).
struct Streamed {
    pos: usize,
    result: PointResult,
    cache_probe: Option<(u64, bool)>,
    eval_ns: u64,
}

/// Emit the canonical per-point event sequence — dispatch, cache
/// hit/miss, frontier outcomes, delivery, journal-flush boundary —
/// for one delivered point. Shared by the checkpoint replay loop and
/// the live delivery loop, so a resumed campaign's trace is
/// byte-identical to an uninterrupted one. `cache_counts` accumulates
/// (hits, misses) for the `campaign.end` aggregates.
#[allow(clippy::too_many_arguments)] // flat mirror of the event order
fn emit_point_events(
    trace: &dyn TraceSink,
    pos: usize,
    index: usize,
    cache_probe: Option<(u64, bool)>,
    outcomes: Option<Vec<InsertOutcome>>,
    eval_ns: Option<u64>,
    flush_every: Option<usize>,
    cache_counts: &mut (u64, u64),
) {
    trace.record_with(TraceEvent::PointDispatch { pos, index }, eval_ns);
    if let Some((key, hit)) = cache_probe {
        if hit {
            cache_counts.0 += 1;
            trace.record(TraceEvent::CacheHit { pos, key });
        } else {
            cache_counts.1 += 1;
            trace.record(TraceEvent::CacheMiss { pos, key });
        }
    }
    if let Some(outcomes) = outcomes {
        trace.record(TraceEvent::FrontierObserve { pos, outcomes });
    }
    trace.record(TraceEvent::PointDeliver { pos, index });
    if let Some(every) = flush_every {
        if (pos + 1) % every == 0 {
            trace.record(TraceEvent::JournalFlush { upto: pos + 1 });
        }
    }
}

/// Lock a campaign-shared resource (point cache, live frontier),
/// recovering from poisoning — a panicked worker elsewhere must not take
/// the whole campaign down with it. The single locking policy for every
/// shared-handle consumer: workers, replay, the CLI.
pub fn lock_shared<T>(shared: &Mutex<T>) -> MutexGuard<'_, T> {
    shared.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Lock the shared point cache — [`lock_shared`] under its historical
/// name, kept for source compatibility.
pub fn lock_cache(cache: &Mutex<PointCache>) -> MutexGuard<'_, PointCache> {
    lock_shared(cache)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::SweepSpec;
    use crate::dnn::{model_for, ModelKind};
    use crate::quant::PeType;

    #[test]
    fn run_covers_models_and_space() {
        let spec = SweepSpec::tiny();
        let db = Explorer::over(spec.clone())
            .dataset(Dataset::Cifar10)
            .workers(2)
            .seed(7)
            .run()
            .unwrap();
        assert_eq!(db.spaces.len(), 3); // VGG-16, ResNet-20, ResNet-56
        for space in &db.spaces {
            assert_eq!(space.evals.len(), spec.len());
        }
        assert_eq!(db.stats.evaluations, spec.len() * 3);
        assert!(db.stats.evals_per_sec() > 0.0);
    }

    #[test]
    fn run_matches_serial_evaluate_point_for_point() {
        let spec = SweepSpec::tiny();
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let serial: Vec<Evaluation> =
            spec.iter().map(|c| dse::evaluate(&c, &model, 7)).collect();
        let db = Explorer::over(spec).model(model).workers(4).seed(7).run().unwrap();
        let parallel = &db.spaces[0].evals;
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(parallel) {
            assert_eq!(a.config.id(), b.config.id());
            assert_eq!(a.perf_per_area, b.perf_per_area);
            assert_eq!(a.energy_uj, b.energy_uj);
        }
    }

    #[test]
    fn stream_delivers_points_in_order() {
        let spec = SweepSpec::tiny();
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let explorer = Explorer::over(spec.clone()).model(model).workers(4).seed(7);
        let mut indices = Vec::new();
        let stats = explorer
            .stream(|point| {
                assert_eq!(point.evals.len(), 1);
                indices.push(point.index);
            })
            .unwrap();
        assert_eq!(indices, (0..spec.len()).collect::<Vec<_>>());
        assert_eq!(stats.design_points, spec.len());
    }

    #[test]
    fn sharded_streams_partition_the_space() {
        let spec = SweepSpec::tiny();
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let mut seen = Vec::new();
        for shard in 0..3 {
            Explorer::over(spec.clone())
                .model(model.clone())
                .workers(2)
                .shard(shard, 3)
                .stream(|point| seen.push(point.index))
                .unwrap();
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..spec.len()).collect::<Vec<_>>());
    }

    #[test]
    fn empty_model_set_is_invalid_config() {
        let err = Explorer::over(SweepSpec::tiny()).run().unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
    }

    #[test]
    fn empty_model_set_with_frontier_and_checkpoint_is_invalid_config() {
        use crate::pareto::CampaignFrontier;
        // Regression guard: `stream` binds the frontier and builds the
        // journal manifest with `self.models[0]` / `self.models[0].dataset`
        // — validate() must reject the empty model set (typed, never a
        // panic) before either path is reached, on both entry points.
        let frontier = Arc::new(Mutex::new(CampaignFrontier::new()));
        let journal = std::env::temp_dir()
            .join(format!("qadam_guard_{}.jsonl", std::process::id()));
        let explorer = Explorer::over(SweepSpec::tiny())
            .frontier(frontier.clone())
            .checkpoint(&journal, 1);
        let err = explorer.stream(|_| {}).unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        assert!(err.to_string().contains("no models to evaluate"), "{err}");
        let err = explorer.run().unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        // Rejected before any side effect: the frontier stayed unbound
        // and the journal file was never created.
        assert!(lock_shared(&frontier).models().is_empty());
        assert!(!journal.exists(), "journal must not be created for a rejected campaign");
    }

    #[test]
    fn empty_axis_is_invalid_config_naming_the_axis() {
        let mut spec = SweepSpec::tiny();
        spec.glb_kib.clear();
        let err = Explorer::over(spec).dataset(Dataset::Cifar10).run().unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        assert!(err.to_string().contains("'glb_kib'"), "{err}");
        // Empty *model* axes are named too — never the generic
        // "no models to evaluate" message.
        let err = Explorer::over(SweepSpec::tiny())
            .dataset(Dataset::Cifar10)
            .model_axes(ModelAxes { width_mults: vec![0.5], depth_mults: vec![] })
            .run()
            .unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        assert!(err.to_string().contains("model axis 'depth'"), "{err}");
        assert!(!err.to_string().contains("no models to evaluate"), "{err}");
    }

    #[test]
    fn joint_run_produces_variant_major_spaces() {
        let spec = SweepSpec::tiny();
        let axes = ModelAxes { width_mults: vec![0.5, 1.0], depth_mults: vec![1] };
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let db = Explorer::over(spec.clone())
            .model(model.clone())
            .model_axes(axes.clone())
            .workers(3)
            .seed(7)
            .run()
            .unwrap();
        // One space per (variant, base model), variant-major.
        assert_eq!(db.spaces.len(), 2);
        assert_eq!(db.spaces[0].model_name, "ResNet-20@w0.5d1");
        assert_eq!(db.spaces[1].model_name, "ResNet-20");
        assert_eq!(db.stats.design_points, 2 * spec.len());
        // Each variant's space equals the serial evaluation of its
        // scaled model over the hardware sweep, bit for bit.
        for (variant_idx, space) in db.spaces.iter().enumerate() {
            let variant = axes.variant(variant_idx).unwrap();
            let scaled = crate::dnn::scale_model(&model, variant.width, variant.depth);
            let serial: Vec<Evaluation> =
                spec.iter().map(|c| dse::evaluate(&c, &scaled, 7)).collect();
            assert_eq!(space.evals.len(), serial.len(), "{}", space.model_name);
            for (a, b) in space.evals.iter().zip(&serial) {
                assert_eq!(a, b, "{}", space.model_name);
            }
        }
    }

    #[test]
    fn joint_stream_orders_variant_blocks() {
        let spec = SweepSpec::tiny();
        let space = crate::arch::DesignSpace::new(
            spec.clone(),
            ModelAxes { width_mults: vec![0.5, 1.0], depth_mults: vec![1] },
        );
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let mut indices = Vec::new();
        Explorer::over(space.clone())
            .model(model)
            .workers(4)
            .seed(7)
            .stream(|point| indices.push(point.index))
            .unwrap();
        assert_eq!(indices, (0..space.len()).collect::<Vec<_>>());
    }

    #[test]
    fn trivial_model_axes_database_is_bit_identical_to_hardware_only() {
        let spec = SweepSpec::tiny();
        let plain = Explorer::over(spec.clone())
            .dataset(Dataset::Cifar10)
            .workers(2)
            .seed(7)
            .run()
            .unwrap();
        let joint = Explorer::over(spec)
            .dataset(Dataset::Cifar10)
            .model_axes(ModelAxes::default())
            .workers(2)
            .seed(7)
            .run()
            .unwrap();
        assert_eq!(
            plain.to_json().to_string_pretty(),
            joint.to_json().to_string_pretty(),
            "trivial model axes must not change campaign artifacts"
        );
    }

    #[test]
    fn bad_shard_is_invalid_config() {
        let err = Explorer::over(SweepSpec::tiny())
            .dataset(Dataset::Cifar10)
            .shard(3, 3)
            .run()
            .unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
    }

    #[test]
    fn int16_free_space_explores_but_has_no_baseline() {
        let spec = SweepSpec { pe_types: vec![PeType::LightPe1], ..SweepSpec::tiny() };
        let db = Explorer::over(spec)
            .model(model_for(ModelKind::ResNet20, Dataset::Cifar10))
            .workers(2)
            .run()
            .unwrap();
        // Exploration itself succeeds; the paper normalization cannot.
        assert!(!db.spaces[0].evals.is_empty());
        let err = db.headline_geomean().unwrap_err();
        assert_eq!(err.kind(), "missing_baseline");
        let err = dse::normalize(&db.spaces[0].evals).unwrap_err();
        assert!(matches!(err, Error::MissingBaseline(_)));
    }

    #[test]
    fn random_strategy_touches_only_n_points() {
        let spec = SweepSpec::default();
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let explorer = Explorer::over(spec.clone())
            .model(model)
            .workers(2)
            .seed(7)
            .strategy(crate::pareto::RandomSample { n: 5, seed: 11 });
        let db = explorer.run().unwrap();
        assert_eq!(db.stats.design_points, 5);
        assert_eq!(db.spaces[0].evals.len(), 5);
        // Every evaluated config is a genuine member of the sweep, and
        // indices stream in ascending cross-product order.
        let mut indices = Vec::new();
        explorer.stream(|point| indices.push(point.index)).unwrap();
        assert_eq!(indices.len(), 5);
        assert!(indices.windows(2).all(|w| w[0] < w[1]));
        assert!(*indices.last().unwrap() < spec.len());
    }

    #[test]
    fn frontier_tracks_streamed_points_live() {
        use crate::pareto::CampaignFrontier;
        let spec = SweepSpec::tiny();
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let frontier = Arc::new(Mutex::new(CampaignFrontier::new()));
        Explorer::over(spec.clone())
            .model(model.clone())
            .workers(2)
            .seed(7)
            .frontier(frontier.clone())
            .run()
            .unwrap();
        let guard = lock_shared(&frontier);
        assert_eq!(guard.models().len(), 1);
        let front = guard.models()[0].front();
        assert!(!front.is_empty());
        assert_eq!(front.offered(), spec.len(), "every streamed point must be offered");
        // The streamed front equals the post-hoc front of the serial space.
        let evals: Vec<Evaluation> =
            spec.iter().map(|c| dse::evaluate(&c, &model, 7)).collect();
        let points: Vec<Vec<f64>> =
            evals.iter().map(|e| vec![e.perf_per_area, e.energy_uj]).collect();
        let batch = dse::pareto_front(
            &points,
            &[dse::Orientation::Maximize, dse::Orientation::Minimize],
        );
        assert_eq!(front.indices(), batch);
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let spec = SweepSpec::tiny();
        let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
        let one = Explorer::over(spec.clone()).model(model.clone()).workers(1).seed(3);
        let many = Explorer::over(spec).model(model).workers(8).seed(3);
        let a = one.run().unwrap();
        let b = many.run().unwrap();
        for (x, y) in a.spaces[0].evals.iter().zip(&b.spaces[0].evals) {
            assert_eq!(x.perf_per_area, y.perf_per_area);
            assert_eq!(x.energy_uj, y.energy_uj);
        }
    }
}
