//! Campaign result aggregation: per-model evaluation spaces, throughput
//! stats, and the headline figures-of-merit the paper's plots need.
//!
//! Produced by [`Explorer::run`](super::Explorer::run); previously owned
//! by the coordinator, which now re-exports these types.

use crate::dnn::Dataset;
use crate::dse::{self, Evaluation};
use crate::error::Result;
use crate::quant::PeType;

/// All evaluations for one (model, dataset) pair. In a joint
/// hardware × model campaign there is one space per *scaled-model
/// variant* (`"ResNet-20@w0.5d2"`), variant-major in the database.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpace {
    /// Model these evaluations belong to. Scaled variants carry the
    /// `@wWdD` suffix of
    /// [`variant_model_name`](crate::dnn::variant_model_name).
    pub model_name: String,
    /// Dataset the model instance targets.
    pub dataset: Dataset,
    /// One evaluation per explored design point, in cross-product order.
    pub evals: Vec<Evaluation>,
}

impl ModelSpace {
    /// The base model family this space belongs to (the name with any
    /// variant suffix stripped).
    pub fn base_name(&self) -> &str {
        crate::dnn::base_model_name(&self.model_name)
    }

    /// The variant suffix (`"w0.5d2"`), or `None` for an unscaled base
    /// model.
    pub fn variant_label(&self) -> Option<&str> {
        self.model_name.split_once('@').map(|(_, label)| label)
    }
}

/// Campaign results across a model set.
///
/// Serialization (`to_json`/`from_json`/`save`/`load`) lives in
/// [`crate::explore::persist`]; the persisted form drops the transient
/// throughput fields (`wall_seconds`, `workers`) so identical campaigns
/// always produce byte-identical files.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalDatabase {
    /// Dataset of the campaign's workload set.
    pub dataset: Dataset,
    /// Round-robin shard this database covers: `(shard, num_shards)`,
    /// `(0, 1)` for the whole space. Persisted, because a shard's local
    /// best INT16 is not the campaign baseline — normalization over a
    /// partial space would silently produce wrong figures.
    pub shard: (usize, usize),
    /// Descriptor of the search strategy that produced this database
    /// (`"exhaustive"` for a full walk). Persisted for the same reason
    /// as `shard`: a strategy-sampled space may not contain the
    /// campaign's true best INT16, so normalizing against the sample's
    /// local best would silently produce wrong figures.
    pub strategy: String,
    /// Per-model evaluation spaces, in the campaign's model order.
    pub spaces: Vec<ModelSpace>,
    /// Campaign throughput metrics.
    pub stats: CampaignStats,
}

/// Campaign throughput metrics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CampaignStats {
    /// Design points actually evaluated (the strategy's selection size
    /// when a non-exhaustive strategy ran).
    pub design_points: usize,
    /// Total evaluations (`design_points` × model count).
    pub evaluations: usize,
    /// Wall-clock duration of the campaign (transient; not persisted).
    pub wall_seconds: f64,
    /// Worker threads used (transient; not persisted).
    pub workers: usize,
}

impl CampaignStats {
    /// Evaluations per second (the §Perf headline for L3).
    pub fn evals_per_sec(&self) -> f64 {
        self.evaluations as f64 / self.wall_seconds.max(1e-9)
    }
}

impl EvalDatabase {
    /// Whether this database covers its whole design space: one shard of
    /// one, walked exhaustively (no sampling strategy).
    pub fn is_whole_space(&self) -> bool {
        self.shard.1 <= 1 && self.strategy == "exhaustive"
    }

    /// Whether any space belongs to a scaled-model variant (a joint
    /// hardware × model campaign).
    pub fn has_model_variants(&self) -> bool {
        self.spaces.iter().any(|space| space.variant_label().is_some())
    }

    /// Guard for the paper normalizations: a shard's (or a sampled
    /// subset's) local best INT16 is not the campaign baseline, so
    /// normalized summaries over a partial space are rejected instead of
    /// silently wrong.
    pub fn ensure_whole_space(&self) -> Result<()> {
        if self.shard.1 > 1 {
            return Err(crate::error::Error::InvalidConfig(format!(
                "database covers shard {}/{} of the design space; merge all shards before \
                 normalizing against the INT16 baseline",
                self.shard.0, self.shard.1
            )));
        }
        if self.strategy != "exhaustive" {
            return Err(crate::error::Error::InvalidConfig(format!(
                "database was sampled by strategy '{}'; its local best INT16 is not the \
                 campaign baseline — rerun exhaustively to normalize",
                self.strategy
            )));
        }
        Ok(())
    }

    /// Headline ratios per model (Fig. 4 summary): the geometric-mean
    /// across models is the paper's "on average across all workloads".
    /// Fails with [`Error::MissingBaseline`](crate::Error::MissingBaseline)
    /// when a space has no INT16 points, and with
    /// [`Error::InvalidConfig`](crate::Error::InvalidConfig) on a sharded
    /// database (see [`Self::ensure_whole_space`]).
    pub fn headline_per_model(&self) -> Result<Vec<(String, Vec<(PeType, f64, f64)>)>> {
        self.ensure_whole_space()?;
        self.spaces
            .iter()
            .map(|s| Ok((s.model_name.clone(), dse::headline_ratios(&s.evals)?)))
            .collect()
    }

    /// Geometric-mean headline ratios across this dataset's models:
    /// (pe, perf/area gain, energy gain).
    ///
    /// The geomean inputs are ratios of best perf/area and best energy —
    /// strictly positive by construction (every evaluation has positive
    /// area, latency, and energy), and only PE types present in the space
    /// contribute, so the sample vectors are non-empty. [`geomean`]'s 0
    /// sentinel (empty/non-positive input) therefore cannot occur here;
    /// if it ever surfaced it would be a bug upstream, not a valid
    /// headline.
    ///
    /// [`geomean`]: crate::util::stats::geomean
    pub fn headline_geomean(&self) -> Result<Vec<(PeType, f64, f64)>> {
        let per_model = self.headline_per_model()?;
        Ok(PeType::ALL
            .iter()
            .filter(|&&pe| {
                // Skip PE types absent from the explored space.
                per_model
                    .iter()
                    .any(|(_, rs)| rs.iter().any(|(p, _, _)| *p == pe))
            })
            .map(|&pe| {
                let ppa: Vec<f64> = per_model
                    .iter()
                    .filter_map(|(_, rs)| {
                        rs.iter().find(|(p, _, _)| *p == pe).map(|(_, a, _)| *a)
                    })
                    .collect();
                let energy: Vec<f64> = per_model
                    .iter()
                    .filter_map(|(_, rs)| {
                        rs.iter().find(|(p, _, _)| *p == pe).map(|(_, _, e)| *e)
                    })
                    .collect();
                (
                    pe,
                    crate::util::stats::geomean(&ppa),
                    crate::util::stats::geomean(&energy),
                )
            })
            .collect())
    }
}
