//! Campaign persistence: the schema-versioned JSON serialization of the
//! exploration artifacts, the content-addressed point cache, and the
//! append-only checkpoint journal behind resumable campaigns.
//!
//! Three durable artifacts, all written through [`crate::util::json`] in
//! its canonical form (sorted keys, shortest round-trip numbers) so that
//! identical campaigns produce byte-identical, diffable files:
//!
//! * **Evaluation database** — [`EvalDatabase::save`]/[`EvalDatabase::load`]
//!   persist a whole campaign (`qadam dse --save/--load`); the report
//!   generator can re-render Figs. 4–6 from disk without re-running.
//! * **Point cache** — [`PointCache`] maps [`point_key`] (a stable FNV-1a
//!   digest of design point × synth seed × model set) to the full
//!   evaluation vector, turning repeat campaigns over overlapping spaces
//!   into near-free lookups. `Explorer::cache` wires it into the workers.
//! * **Checkpoint journal** — [`JournalWriter`] appends one JSON line per
//!   delivered design point during `Explorer::stream`; a killed campaign
//!   resumes from the last flushed point and produces a byte-identical
//!   database to an uninterrupted run. The header pins a
//!   [`CampaignManifest`] (sweep fingerprint, seed, shard, model set) and
//!   resume against a different campaign is rejected with
//!   [`Error::InvalidConfig`].
//!
//! Every loader returns typed errors — [`Error::Io`] for filesystem
//! failures, [`Error::ParseError`] for truncated or garbled content —
//! and never panics on corrupt input. Two deliberate leniencies, both
//! for the exact crash the journal exists to survive: a journal whose
//! *final* line is an incomplete fragment (the torn write of a killed
//! process) drops that fragment and re-evaluates from there, and a
//! journal killed before its header line was flushed is restarted from
//! scratch. Database and cache saves are atomic (temp file + rename),
//! so a crash mid-save never destroys the previous valid artifact.
//!
//! All persisted documents carry `{"kind": ..., "schema": N}`; readers
//! reject unknown kinds and future schema versions with a parse error
//! instead of misinterpreting the payload.

use std::collections::BTreeMap;
use std::fs::{self, OpenOptions};
use std::io::{BufWriter, Seek, SeekFrom, Write};
use std::path::Path;

use super::db::{CampaignStats, EvalDatabase, ModelSpace};
use super::PointResult;
use crate::dnn::{Dataset, Model};
use crate::dse::Evaluation;
use crate::error::{Error, Result};
use crate::util::json::{num, obj, s, Json};
use crate::util::Fnv64;

/// Newest schema version this build reads and writes. Bump on any
/// change to the serialized field set; readers reject versions outside
/// [`BASE_SCHEMA_VERSION`]`..=`[`SCHEMA_VERSION`] with
/// [`Error::ParseError`] rather than guessing. History: v1 — initial
/// persistence layer; v2 — checkpoint manifests pin the campaign's
/// search strategy and the streaming frontier document
/// (`qadam.frontier`) joined the family; v3 — checkpoint manifests
/// optionally pin the QSL campaign-spec fingerprint (`campaign_fp`),
/// so resuming under an edited spec is rejected; v4 — checkpoint
/// manifests of *joint* hardware × model campaigns pin the model axes
/// (`model_axes`), and the sweep fingerprint covers them.
pub const SCHEMA_VERSION: usize = 4;

/// Oldest schema version this build reads — and the version every
/// document *writes* unless it carries joint-space content. Documents
/// declare the minimum version able to read them: a hardware-only
/// campaign's database, cache, journal, and frontier are byte-identical
/// to a pre-joint (v3) build's, so its journals stay interchangeable.
/// Joint content claims v4: a manifest pinning non-trivial
/// [`ModelAxes`](crate::arch::ModelAxes), and a database holding
/// scaled-model-variant spaces. (Point caches stay v3 — their keys are
/// opaque content addresses that can never alias across builds — and a
/// frontier's campaign binding already rejects any pre-joint reattach
/// via its joint-space fingerprint.)
pub const BASE_SCHEMA_VERSION: usize = 3;

// ---------------------------------------------------------------------------
// Field access helpers (typed errors instead of panics). Crate-visible:
// the frontier archive (`crate::pareto::frontier`) persists through the
// same canonical layer.

fn field_f64(json: &Json, key: &str) -> Result<f64> {
    json.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| Error::ParseError(format!("missing numeric field '{key}'")))
}

pub(crate) fn field_usize(json: &Json, key: &str) -> Result<usize> {
    json.get(key)
        .and_then(Json::as_i64)
        .filter(|v| *v >= 0)
        .map(|v| v as usize)
        .ok_or_else(|| Error::ParseError(format!("missing integer field '{key}'")))
}

pub(crate) fn field_str<'a>(json: &'a Json, key: &str) -> Result<&'a str> {
    json.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| Error::ParseError(format!("missing string field '{key}'")))
}

pub(crate) fn field_arr<'a>(json: &'a Json, key: &str) -> Result<&'a [Json]> {
    json.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::ParseError(format!("missing array field '{key}'")))
}

pub(crate) fn field_u64_hex(json: &Json, key: &str) -> Result<u64> {
    let text = field_str(json, key)?;
    u64::from_str_radix(text, 16)
        .map_err(|_| Error::ParseError(format!("field '{key}' is not a hex u64: '{text}'")))
}

pub(crate) fn hex(value: u64) -> String {
    format!("{value:016x}")
}

fn field_dataset(json: &Json, key: &str) -> Result<Dataset> {
    let name = field_str(json, key)?;
    Dataset::parse(name)
        .ok_or_else(|| Error::ParseError(format!("unknown dataset '{name}' in field '{key}'")))
}

/// Validate the `{"kind", "schema"}` envelope shared by all artifacts.
pub(crate) fn check_envelope(json: &Json, kind: &str) -> Result<()> {
    let found = field_str(json, "kind")?;
    if found != kind {
        return Err(Error::ParseError(format!(
            "expected a '{kind}' document, found kind '{found}'"
        )));
    }
    let schema = field_usize(json, "schema")?;
    if !(BASE_SCHEMA_VERSION..=SCHEMA_VERSION).contains(&schema) {
        return Err(Error::ParseError(format!(
            "unsupported {kind} schema version {schema} (this build reads versions \
             {BASE_SCHEMA_VERSION} through {SCHEMA_VERSION}; regenerate the file)"
        )));
    }
    Ok(())
}

/// Validate a `{"kind", "schema"}` envelope against an *exact* schema
/// version — for artifact families that version independently of the
/// campaign schema lineage (the serve status journal, the trace and
/// timing documents). [`check_envelope`]'s range check would wrongly
/// judge their schema numbers against
/// [`BASE_SCHEMA_VERSION`]`..=`[`SCHEMA_VERSION`].
pub(crate) fn check_envelope_exact(json: &Json, kind: &str, version: usize) -> Result<()> {
    let found = field_str(json, "kind")?;
    if found != kind {
        return Err(Error::ParseError(format!(
            "expected a '{kind}' document, found kind '{found}'"
        )));
    }
    let schema = field_usize(json, "schema")?;
    if schema != version {
        return Err(Error::ParseError(format!(
            "unsupported {kind} schema version {schema} (this build reads version \
             {version}; regenerate the file)"
        )));
    }
    Ok(())
}

/// The envelope every document without joint-space content writes: the
/// minimum version able to read it (see [`BASE_SCHEMA_VERSION`]).
pub(crate) fn envelope(kind: &str) -> Vec<(&str, Json)> {
    envelope_at(kind, BASE_SCHEMA_VERSION)
}

/// An envelope at an explicit schema version (joint-campaign manifests
/// claim [`SCHEMA_VERSION`]).
pub(crate) fn envelope_at(kind: &str, version: usize) -> Vec<(&str, Json)> {
    vec![("kind", s(kind)), ("schema", num(version as f64))]
}

/// Write `text` to `path` atomically: temp sibling + rename, so a crash
/// mid-save never leaves a torn file where a valid artifact used to be.
pub(crate) fn write_atomic(path: &Path, text: &str) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            fs::create_dir_all(parent)?;
        }
    }
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    fs::write(&tmp, text)?;
    fs::rename(&tmp, path)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Evaluation / ModelSpace / CampaignStats / EvalDatabase serialization.

impl Evaluation {
    /// Serialize every metric plus the originating config. Numbers use
    /// the shortest round-trip rendering, so `from_json(to_json(e)) == e`
    /// bit-for-bit.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("config", self.config.to_json()),
            ("area_mm2", num(self.area_mm2)),
            ("clock_ghz", num(self.clock_ghz)),
            ("latency_ms", num(self.latency_ms)),
            ("inf_per_s", num(self.inf_per_s)),
            ("perf_per_area", num(self.perf_per_area)),
            ("energy_uj", num(self.energy_uj)),
            ("dram_energy_uj", num(self.dram_energy_uj)),
            ("utilization", num(self.utilization)),
        ])
    }

    /// Deserialize from [`Self::to_json`] output.
    pub fn from_json(json: &Json) -> Result<Self> {
        let config_json = json
            .get("config")
            .ok_or_else(|| Error::ParseError("evaluation missing field 'config'".into()))?;
        Ok(Self {
            config: crate::arch::AcceleratorConfig::from_json(config_json)?,
            area_mm2: field_f64(json, "area_mm2")?,
            clock_ghz: field_f64(json, "clock_ghz")?,
            latency_ms: field_f64(json, "latency_ms")?,
            inf_per_s: field_f64(json, "inf_per_s")?,
            perf_per_area: field_f64(json, "perf_per_area")?,
            energy_uj: field_f64(json, "energy_uj")?,
            dram_energy_uj: field_f64(json, "dram_energy_uj")?,
            utilization: field_f64(json, "utilization")?,
        })
    }
}

impl ModelSpace {
    /// Serialize the model label and its evaluation space.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("model_name", s(&self.model_name)),
            ("dataset", s(self.dataset.name())),
            ("evals", Json::Arr(self.evals.iter().map(Evaluation::to_json).collect())),
        ])
    }

    /// Deserialize from [`Self::to_json`] output.
    pub fn from_json(json: &Json) -> Result<Self> {
        Ok(Self {
            model_name: field_str(json, "model_name")?.to_string(),
            dataset: field_dataset(json, "dataset")?,
            evals: field_arr(json, "evals")?
                .iter()
                .map(Evaluation::from_json)
                .collect::<Result<_>>()?,
        })
    }
}

impl CampaignStats {
    /// Serialize the campaign-shape fields only. `wall_seconds` and
    /// `workers` are transient throughput observations — persisting them
    /// would make byte-identical campaigns produce differing files — so
    /// they are dropped here and zeroed by [`Self::from_json`].
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("design_points", num(self.design_points as f64)),
            ("evaluations", num(self.evaluations as f64)),
        ])
    }

    /// Deserialize from [`Self::to_json`] output (transient fields zero).
    pub fn from_json(json: &Json) -> Result<Self> {
        Ok(Self {
            design_points: field_usize(json, "design_points")?,
            evaluations: field_usize(json, "evaluations")?,
            wall_seconds: 0.0,
            workers: 0,
        })
    }
}

impl EvalDatabase {
    /// Serialize the whole campaign to a schema-versioned document,
    /// including the shard identity and strategy descriptor (a shard's —
    /// or a sampled subset's — local best INT16 is not the campaign
    /// baseline, so loaders must know the coverage). A database holding
    /// scaled-model variants claims [`SCHEMA_VERSION`] so pre-joint
    /// readers reject it cleanly instead of misreading each variant as
    /// an independent model; hardware-only databases stay at
    /// [`BASE_SCHEMA_VERSION`], byte-identical to pre-joint builds.
    pub fn to_json(&self) -> Json {
        let version = if self.has_model_variants() {
            SCHEMA_VERSION
        } else {
            BASE_SCHEMA_VERSION
        };
        let mut fields = envelope_at("qadam.evaldb", version);
        fields.push(("dataset", s(self.dataset.name())));
        fields.push(("shard", num(self.shard.0 as f64)));
        fields.push(("num_shards", num(self.shard.1 as f64)));
        fields.push(("strategy", s(&self.strategy)));
        fields.push(("spaces", Json::Arr(self.spaces.iter().map(ModelSpace::to_json).collect())));
        fields.push(("stats", self.stats.to_json()));
        obj(fields)
    }

    /// Deserialize from [`Self::to_json`] output; rejects other document
    /// kinds and schema versions with [`Error::ParseError`].
    pub fn from_json(json: &Json) -> Result<Self> {
        check_envelope(json, "qadam.evaldb")?;
        let stats_json = json
            .get("stats")
            .ok_or_else(|| Error::ParseError("database missing field 'stats'".into()))?;
        let shard = (field_usize(json, "shard")?, field_usize(json, "num_shards")?);
        if shard.1 == 0 || shard.0 >= shard.1 {
            return Err(Error::ParseError(format!(
                "database has invalid shard designator {}/{}",
                shard.0, shard.1
            )));
        }
        Ok(Self {
            dataset: field_dataset(json, "dataset")?,
            shard,
            strategy: field_str(json, "strategy")?.to_string(),
            spaces: field_arr(json, "spaces")?
                .iter()
                .map(ModelSpace::from_json)
                .collect::<Result<_>>()?,
            stats: CampaignStats::from_json(stats_json)?,
        })
    }

    /// Write the database as pretty-printed canonical JSON (atomic:
    /// temp file + rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        write_atomic(path, &self.to_json().to_string_pretty())
    }

    /// Load a database written by [`Self::save`]. Missing files are
    /// [`Error::Io`]; truncated or garbled ones are [`Error::ParseError`].
    pub fn load(path: &Path) -> Result<Self> {
        let text = fs::read_to_string(path)?;
        let json = Json::parse(&text)
            .map_err(|e| Error::ParseError(format!("{}: {e}", path.display())))?;
        Self::from_json(&json)
    }
}

// ---------------------------------------------------------------------------
// Content-addressed point cache.

/// Content address of one unit of exploration work: the evaluation of a
/// design point against a model set under a synthesis seed.
///
/// The key is a streaming FNV-1a 64-bit digest over (1) the canonical
/// JSON of the [`AcceleratorConfig`](crate::arch::AcceleratorConfig),
/// (2) the little-endian seed bytes, and (3) per model: name, dataset,
/// and the kind + full shape of every layer — every input that the
/// deterministic `synthesize` + `evaluate_with_synth` pipeline consumes
/// (the mapper tiles each layer's geometry against the config, so totals
/// alone would alias distinct models). Equal inputs therefore always
/// hash equal across runs and platforms, and any field change produces a
/// different key.
pub fn point_key(config: &crate::arch::AcceleratorConfig, seed: u64, models: &[Model]) -> u64 {
    point_key_with(config, seed, models, &mut String::new())
}

/// [`point_key`] with a caller-supplied scratch buffer for the config's
/// canonical-JSON render — the Explorer's workers thread one buffer per
/// thread through every point, so steady-state cache probing performs no
/// heap allocation. Byte-identical to [`point_key`] (the render is
/// equality-tested against `config.to_json().to_string_canonical()`).
pub fn point_key_with(
    config: &crate::arch::AcceleratorConfig,
    seed: u64,
    models: &[Model],
    scratch: &mut String,
) -> u64 {
    scratch.clear();
    render_config_canonical(config, scratch);
    let mut hasher = Fnv64::new();
    hasher.update(scratch.as_bytes());
    hasher.update(&seed.to_le_bytes());
    for model in models {
        hasher.update(model.name.as_bytes());
        hasher.update(model.dataset.name().as_bytes());
        hasher.update(&(model.layers.len() as u64).to_le_bytes());
        for layer in &model.layers {
            let kind_tag: u8 = match layer.kind {
                crate::dnn::LayerKind::Conv => 0,
                crate::dnn::LayerKind::FullyConnected => 1,
                crate::dnn::LayerKind::Pool => 2,
            };
            hasher.update(&[kind_tag]);
            for dim in
                [layer.in_hw, layer.in_c, layer.out_c, layer.kernel, layer.stride, layer.padding]
            {
                hasher.update(&(dim as u64).to_le_bytes());
            }
        }
    }
    hasher.finish()
}

/// Render `config.to_json().to_string_canonical()` into `out` without
/// building the intermediate [`Json`] tree (the tree costs one `BTreeMap`
/// plus ~9 key `String`s per call — pure overhead on the cache-key hot
/// path). The key order below IS the canonical order: the canonical form
/// sorts object keys, so the fields appear alphabetically. Byte-for-byte
/// equality with the tree render is pinned by a test.
fn render_config_canonical(config: &crate::arch::AcceleratorConfig, out: &mut String) {
    use std::fmt::Write as _;
    let field = |out: &mut String, key: &str, value: f64| {
        out.push('"');
        out.push_str(key);
        out.push_str("\":");
        // Json::Num rendering: integral values in i64 form, everything
        // else via f64's shortest round-trip Display.
        if value.fract() == 0.0 && value.abs() < 9e15 {
            let _ = write!(out, "{}", value as i64);
        } else {
            let _ = write!(out, "{value}");
        }
    };
    out.push('{');
    field(out, "clock_ghz", config.clock_ghz);
    out.push(',');
    field(out, "cols", config.cols as f64);
    out.push(',');
    field(out, "dram_bw_gbps", config.dram_bw_gbps);
    out.push(',');
    field(out, "filter_spad", config.spad.filter_entries as f64);
    out.push(',');
    field(out, "glb_kib", config.glb_kib as f64);
    out.push(',');
    field(out, "ifmap_spad", config.spad.ifmap_entries as f64);
    out.push_str(",\"pe\":");
    crate::util::json::write_escaped(out, config.pe.name());
    out.push(',');
    field(out, "psum_spad", config.spad.psum_entries as f64);
    out.push(',');
    field(out, "rows", config.rows as f64);
    out.push('}');
}

/// Content-addressed cache of fully evaluated design points, keyed by
/// [`point_key`]. `Explorer::cache` consults it before synthesizing, so
/// repeat campaigns over overlapping spaces skip the synthesis + mapping
/// pipeline entirely; hits are bit-identical to recomputation because the
/// pipeline is deterministic in the key's inputs.
///
/// ```
/// use qadam::arch::AcceleratorConfig;
/// use qadam::dnn::{model_for, Dataset, ModelKind};
/// use qadam::explore::{point_key, PointCache};
///
/// let config = AcceleratorConfig::default();
/// let model = model_for(ModelKind::ResNet20, Dataset::Cifar10);
/// let key = point_key(&config, 7, std::slice::from_ref(&model));
///
/// let mut cache = PointCache::new();
/// assert!(cache.lookup(key).is_none()); // cold: a miss
/// let evals = vec![qadam::dse::evaluate(&config, &model, 7)];
/// cache.store(key, evals.clone());
/// assert_eq!(cache.lookup(key).unwrap(), evals); // warm: bit-identical
/// assert_eq!((cache.hits(), cache.misses()), (1, 1));
/// ```
#[derive(Debug, Clone, Default)]
pub struct PointCache {
    entries: BTreeMap<u64, Vec<Evaluation>>,
    hits: u64,
    misses: u64,
    /// Completed saves of this cache lineage (persisted). `save` bumps
    /// it under the caller's `&mut` exclusivity, so when several
    /// campaigns share one `Arc<Mutex<PointCache>>` their saves are
    /// totally ordered: the file on disk always carries the merged
    /// entry set of *every* save that happened-before it, and its
    /// generation says how many that was. A torn or lost save is
    /// therefore observable as a generation gap instead of silently
    /// resurrecting a cache missing another tenant's entries.
    generation: u64,
}

impl PointCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of cached design points.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the cache holds no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total cached evaluations across all design points.
    pub fn total_evaluations(&self) -> usize {
        self.entries.values().map(Vec::len).sum()
    }

    /// Lifetime lookups served from the cache. Persisted across
    /// save/load, so a loaded cache resumes its lineage's totals;
    /// callers wanting per-run deltas snapshot before and after.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lifetime lookups that missed (persisted, like [`Self::hits`]).
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Completed saves of this cache lineage (see [`Self::save`]).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Counted lookup: clones the cached evaluations on hit.
    pub fn lookup(&mut self, key: u64) -> Option<Vec<Evaluation>> {
        match self.entries.get(&key) {
            Some(evals) => {
                self.hits += 1;
                Some(evals.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Uncounted read access.
    pub fn get(&self, key: u64) -> Option<&[Evaluation]> {
        self.entries.get(&key).map(Vec::as_slice)
    }

    /// Insert (or replace) the evaluations for a key.
    pub fn store(&mut self, key: u64, evals: Vec<Evaluation>) {
        self.entries.insert(key, evals);
    }

    /// Drop all entries and reset the hit/miss counters.
    pub fn clear(&mut self) {
        self.entries.clear();
        self.hits = 0;
        self.misses = 0;
    }

    /// Serialize to a schema-versioned document, lifetime hit/miss
    /// counters included — `qadam cache` reports hit rate over the
    /// cache's whole lineage, not just the last process. Keys render as
    /// fixed-width hex so the entry order — and thus the file — is
    /// canonical.
    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|(key, evals)| {
                obj(vec![
                    ("key", s(&hex(*key))),
                    ("evals", Json::Arr(evals.iter().map(Evaluation::to_json).collect())),
                ])
            })
            .collect();
        let mut fields = envelope("qadam.pointcache");
        fields.push(("entries", Json::Arr(entries)));
        fields.push(("generation", num(self.generation as f64)));
        fields.push(("hits", num(self.hits as f64)));
        fields.push(("misses", num(self.misses as f64)));
        obj(fields)
    }

    /// Deserialize from [`Self::to_json`] output. The `generation`,
    /// `hits`, and `misses` fields are all optional (earlier caches did
    /// not carry them) and default to 0.
    pub fn from_json(json: &Json) -> Result<Self> {
        check_envelope(json, "qadam.pointcache")?;
        let mut cache = Self::new();
        for entry in field_arr(json, "entries")? {
            let key = field_u64_hex(entry, "key")?;
            let evals = field_arr(entry, "evals")?
                .iter()
                .map(Evaluation::from_json)
                .collect::<Result<_>>()?;
            cache.entries.insert(key, evals);
        }
        let opt_u64 = |key: &str| {
            json.get(key)
                .and_then(Json::as_i64)
                .filter(|v| *v >= 0)
                .map(|v| v as u64)
                .unwrap_or(0)
        };
        cache.generation = opt_u64("generation");
        cache.hits = opt_u64("hits");
        cache.misses = opt_u64("misses");
        Ok(cache)
    }

    /// Write the cache as pretty-printed canonical JSON (atomic: temp
    /// file + rename), bumping the save generation first. The `&mut`
    /// receiver forces concurrent savers of a shared cache through its
    /// mutex, so saves serialize and the persisted file monotonically
    /// accumulates every tenant's entries.
    pub fn save(&mut self, path: &Path) -> Result<()> {
        self.generation += 1;
        write_atomic(path, &self.to_json().to_string_pretty())
    }

    /// Load a cache written by [`Self::save`]; counters resume the
    /// lineage's persisted lifetime totals (zero for caches written
    /// before the counters were persisted).
    pub fn load(path: &Path) -> Result<Self> {
        let text = fs::read_to_string(path)?;
        let json = Json::parse(&text)
            .map_err(|e| Error::ParseError(format!("{}: {e}", path.display())))?;
        Self::from_json(&json)
    }
}

// ---------------------------------------------------------------------------
// Checkpoint journal.

/// Identity of a campaign, pinned in the journal header. Resuming
/// validates every field so a journal can never be replayed into a
/// campaign it was not written for.
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignManifest {
    /// [`SweepSpec::fingerprint`](crate::arch::SweepSpec::fingerprint).
    pub spec_fingerprint: u64,
    /// Synthesis-noise seed of the campaign.
    pub seed: u64,
    /// Round-robin shard this campaign covers.
    pub shard: usize,
    /// Total number of round-robin shards.
    pub num_shards: usize,
    /// Design points this (shard of the) campaign will deliver — the
    /// strategy's selection size, not the raw space size.
    pub total: usize,
    /// Dataset label of the workload set.
    pub dataset: String,
    /// Model names in evaluation order.
    pub models: Vec<String>,
    /// [`Strategy::descriptor`](crate::pareto::Strategy::descriptor) of
    /// the campaign's search strategy (`"exhaustive"` when none is set).
    /// Resuming under a different strategy would replay points the new
    /// selection never visits, so mismatches are rejected.
    pub strategy: String,
    /// The campaign's model-hyperparameter axes. Trivial axes (the
    /// hardware-only default) are not serialized — the manifest stays
    /// byte-identical to a pre-joint build's — while non-trivial axes
    /// are pinned verbatim (schema v4) on top of being covered by
    /// `spec_fingerprint`, so an axes mismatch names itself instead of
    /// surfacing as an opaque fingerprint difference.
    pub model_axes: crate::arch::ModelAxes,
    /// Fingerprint of the campaign's QSL canonical identity
    /// ([`Explorer::campaign_fingerprint`](super::Explorer::campaign_fingerprint)),
    /// when the campaign was built from a spec or through the shared
    /// [`ResolvedCampaign`](crate::spec::ResolvedCampaign) path. `None`
    /// for direct `Explorer` campaigns. Any difference — including
    /// present-vs-absent — rejects the resume: an edited spec may
    /// change inputs (custom model shapes) that no other manifest
    /// field sees.
    pub campaign_fp: Option<u64>,
}

impl CampaignManifest {
    /// Serialize as the journal header payload. Hardware-only
    /// campaigns emit [`BASE_SCHEMA_VERSION`] with no `model_axes`
    /// field — byte-identical to pre-joint builds — while joint
    /// campaigns pin their axes under [`SCHEMA_VERSION`].
    pub fn to_json(&self) -> Json {
        let joint = !self.model_axes.is_trivial();
        let mut fields = envelope_at(
            "qadam.journal",
            if joint { SCHEMA_VERSION } else { BASE_SCHEMA_VERSION },
        );
        fields.push(("spec_fingerprint", s(&hex(self.spec_fingerprint))));
        fields.push(("seed", s(&hex(self.seed))));
        fields.push(("shard", num(self.shard as f64)));
        fields.push(("num_shards", num(self.num_shards as f64)));
        fields.push(("total", num(self.total as f64)));
        fields.push(("dataset", s(&self.dataset)));
        fields.push(("models", Json::Arr(self.models.iter().map(|m| s(m)).collect())));
        fields.push(("strategy", s(&self.strategy)));
        if joint {
            fields.push(("model_axes", self.model_axes.to_json()));
        }
        if let Some(fp) = self.campaign_fp {
            fields.push(("campaign_fp", s(&hex(fp))));
        }
        obj(fields)
    }

    /// Deserialize a journal header payload.
    pub fn from_json(json: &Json) -> Result<Self> {
        check_envelope(json, "qadam.journal")?;
        Ok(Self {
            spec_fingerprint: field_u64_hex(json, "spec_fingerprint")?,
            seed: field_u64_hex(json, "seed")?,
            shard: field_usize(json, "shard")?,
            num_shards: field_usize(json, "num_shards")?,
            total: field_usize(json, "total")?,
            dataset: field_str(json, "dataset")?.to_string(),
            models: field_arr(json, "models")?
                .iter()
                .map(|m| {
                    m.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| Error::ParseError("manifest model names must be strings".into()))
                })
                .collect::<Result<_>>()?,
            strategy: field_str(json, "strategy")?.to_string(),
            model_axes: match json.get("model_axes") {
                None => crate::arch::ModelAxes::default(),
                Some(axes) => crate::arch::ModelAxes::from_json(axes)?,
            },
            campaign_fp: match json.get("campaign_fp") {
                None => None,
                Some(_) => Some(field_u64_hex(json, "campaign_fp")?),
            },
        })
    }

    /// Reject a journal written for a different campaign.
    fn ensure_matches(&self, journal: &CampaignManifest) -> Result<()> {
        let mismatch = |field: &str, journal_val: String, campaign_val: String| {
            Err(Error::InvalidConfig(format!(
                "checkpoint journal was written for a different campaign: {field} differs \
                 (journal: {journal_val}, this campaign: {campaign_val})"
            )))
        };
        // Axes first: when only the model axes moved, the named error
        // beats the opaque joint-fingerprint difference it also causes.
        if journal.model_axes != self.model_axes {
            let render = |axes: &crate::arch::ModelAxes| {
                format!(
                    "width {:?} x depth {:?}",
                    axes.width_mults, axes.depth_mults
                )
            };
            return mismatch(
                "model axes",
                render(&journal.model_axes),
                render(&self.model_axes),
            );
        }
        if journal.spec_fingerprint != self.spec_fingerprint {
            return mismatch(
                "sweep fingerprint",
                hex(journal.spec_fingerprint),
                hex(self.spec_fingerprint),
            );
        }
        if journal.seed != self.seed {
            return mismatch("seed", journal.seed.to_string(), self.seed.to_string());
        }
        if (journal.shard, journal.num_shards) != (self.shard, self.num_shards) {
            return mismatch(
                "shard",
                format!("{}/{}", journal.shard, journal.num_shards),
                format!("{}/{}", self.shard, self.num_shards),
            );
        }
        if journal.total != self.total {
            let (j, c) = (journal.total.to_string(), self.total.to_string());
            return mismatch("design-point count", j, c);
        }
        if journal.dataset != self.dataset {
            return mismatch("dataset", journal.dataset.clone(), self.dataset.clone());
        }
        if journal.models != self.models {
            return mismatch(
                "model set",
                journal.models.join(","),
                self.models.join(","),
            );
        }
        if journal.strategy != self.strategy {
            return mismatch("search strategy", journal.strategy.clone(), self.strategy.clone());
        }
        if journal.campaign_fp != self.campaign_fp {
            let render = |fp: Option<u64>| fp.map_or_else(|| "none".to_string(), hex);
            let hint = match (journal.campaign_fp, self.campaign_fp) {
                (Some(_), Some(_)) => {
                    "the spec was edited since the journal was written; restore the spec or \
                     start a fresh journal"
                }
                (None, Some(_)) => {
                    "the journal was written without a spec fingerprint (direct Explorer API); \
                     resume it the same way, or start a fresh journal"
                }
                (Some(_), None) => {
                    "the journal pins a spec fingerprint but this campaign has none (direct \
                     Explorer API); resume via `qadam run`/`qadam dse`, or start a fresh journal"
                }
                (None, None) => unreachable!("equal fingerprints never mismatch"),
            };
            return Err(Error::InvalidConfig(format!(
                "checkpoint journal campaign-spec fingerprint differs (journal: {}, this \
                 campaign: {}) — {hint}",
                render(journal.campaign_fp),
                render(self.campaign_fp)
            )));
        }
        Ok(())
    }
}

fn entry_to_json(pos: usize, point: &PointResult) -> Json {
    obj(vec![
        ("pos", num(pos as f64)),
        ("index", num(point.index as f64)),
        ("evals", Json::Arr(point.evals.iter().map(Evaluation::to_json).collect())),
    ])
}

fn entry_from_json(json: &Json) -> Result<(usize, PointResult)> {
    let pos = field_usize(json, "pos")?;
    let index = field_usize(json, "index")?;
    let evals: Vec<Evaluation> = field_arr(json, "evals")?
        .iter()
        .map(Evaluation::from_json)
        .collect::<Result<_>>()?;
    let config = evals
        .first()
        .map(|e| e.config.clone())
        .ok_or_else(|| Error::ParseError("journal entry has no evaluations".into()))?;
    Ok((pos, PointResult { index, config, evals }))
}

/// Parse the journal body: header + contiguous entries. Returns the
/// replayable points and the byte length of the valid prefix (everything
/// after it — at most one torn trailing fragment — is discarded on
/// resume). `index_for` maps a delivery position to the cross-product
/// index the campaign's strategy selection assigns it (affine for
/// exhaustive campaigns, a subset walk otherwise); entries that
/// contradict it are corruption. Corruption anywhere else is
/// [`Error::ParseError`] too.
fn parse_journal(
    text: &str,
    campaign: &CampaignManifest,
    index_for: &dyn Fn(usize) -> usize,
) -> Result<(Vec<PointResult>, usize)> {
    let mut segments = text.split_inclusive('\n');
    let header_line = segments
        .next()
        .ok_or_else(|| Error::ParseError("checkpoint journal is empty".into()))?;
    if !header_line.ends_with('\n') {
        return Err(Error::ParseError(
            "checkpoint journal header is truncated (no complete header line)".into(),
        ));
    }
    let header = Json::parse(header_line.trim_end())
        .map_err(|e| Error::ParseError(format!("checkpoint journal header: {e}")))?;
    let journal_manifest = CampaignManifest::from_json(&header)?;
    campaign.ensure_matches(&journal_manifest)?;
    let mut valid_len = header_line.len();
    let mut entries: Vec<PointResult> = Vec::new();
    for segment in segments {
        if !segment.ends_with('\n') {
            // Torn trailing write of a killed run: not flushed, so the
            // resumed campaign re-evaluates from here.
            break;
        }
        let entry_no = entries.len();
        let json = Json::parse(segment.trim_end())
            .map_err(|e| Error::ParseError(format!("checkpoint journal entry {entry_no}: {e}")))?;
        let (pos, point) = entry_from_json(&json)?;
        if pos != entry_no {
            return Err(Error::ParseError(format!(
                "checkpoint journal entries out of order: expected pos {entry_no}, found {pos}"
            )));
        }
        if entries.len() >= campaign.total {
            return Err(Error::ParseError(format!(
                "checkpoint journal has more entries than the campaign's {} design points",
                campaign.total
            )));
        }
        let expected_index = index_for(pos);
        if point.index != expected_index {
            return Err(Error::ParseError(format!(
                "checkpoint journal entry {entry_no} has index {} but the campaign maps pos \
                 {pos} to index {expected_index}",
                point.index
            )));
        }
        if point.evals.len() != campaign.models.len() {
            return Err(Error::ParseError(format!(
                "checkpoint journal entry {entry_no} has {} evaluations for {} models",
                point.evals.len(),
                campaign.models.len()
            )));
        }
        entries.push(point);
        valid_len += segment.len();
    }
    Ok((entries, valid_len))
}

/// Append-only writer for the checkpoint journal. Created (or resumed)
/// by [`JournalWriter::open`]; `Explorer::stream` appends each delivered
/// point and flushes every `every_n` entries, so a killed campaign loses
/// at most `every_n - 1` points of work.
#[derive(Debug)]
pub struct JournalWriter {
    out: BufWriter<fs::File>,
    next_pos: usize,
    every_n: usize,
    since_flush: usize,
    /// Reusable line-accumulation buffer for [`Self::append_batch`]: lines
    /// are staged here and handed to the kernel as one write per flush
    /// boundary instead of two small writes per point.
    scratch: String,
}

impl JournalWriter {
    /// Open a journal for the given campaign. A missing file starts a
    /// fresh journal (header flushed immediately); an existing one is
    /// validated against `manifest`, its flushed points are returned for
    /// replay, and any torn trailing fragment is truncated away before
    /// appending continues. `index_for` maps a delivery position to its
    /// cross-product index under the campaign's strategy selection
    /// (entries are validated against it; see the explorer's stream
    /// pipeline, the only caller).
    pub fn open(
        path: &Path,
        manifest: &CampaignManifest,
        every_n: usize,
        index_for: &dyn Fn(usize) -> usize,
    ) -> Result<(Self, Vec<PointResult>)> {
        let every_n = every_n.max(1);
        if path.exists() {
            let text = fs::read_to_string(path)?;
            // A kill between file creation and the header flush leaves an
            // empty file or a torn header line. That is exactly the crash
            // the journal exists to survive, so start the journal over
            // instead of wedging every future resume on a parse error.
            // The suspect file is renamed aside, never deleted — if it was
            // actually a mistyped `--resume` path pointing at some other
            // newline-less file, the data survives as `<path>.torn`.
            // (A *complete* header line that fails to parse is genuine
            // corruption and still errors below.)
            let torn_header = match text.split_inclusive('\n').next() {
                None => true,
                Some(line) => !line.ends_with('\n'),
            };
            if torn_header {
                let mut aside = path.as_os_str().to_os_string();
                aside.push(".torn");
                fs::rename(path, std::path::PathBuf::from(aside))?;
                return Self::open(path, manifest, every_n, index_for);
            }
            let (entries, valid_len) = parse_journal(&text, manifest, index_for)?;
            let mut file = OpenOptions::new().write(true).open(path)?;
            file.set_len(valid_len as u64)?;
            file.seek(SeekFrom::Start(valid_len as u64))?;
            let next_pos = entries.len();
            let writer = Self {
                out: BufWriter::new(file),
                next_pos,
                every_n,
                since_flush: 0,
                scratch: String::new(),
            };
            Ok((writer, entries))
        } else {
            if let Some(parent) = path.parent() {
                if !parent.as_os_str().is_empty() {
                    fs::create_dir_all(parent)?;
                }
            }
            let file = OpenOptions::new().write(true).create_new(true).open(path)?;
            let mut out = BufWriter::new(file);
            out.write_all(manifest.to_json().to_string_canonical().as_bytes())?;
            out.write_all(b"\n")?;
            out.flush()?;
            let writer =
                Self { out, next_pos: 0, every_n, since_flush: 0, scratch: String::new() };
            Ok((writer, Vec::new()))
        }
    }

    /// Append one delivered point; flushes every `every_n` appends.
    pub fn append(&mut self, point: &PointResult) -> Result<()> {
        self.append_batch(std::iter::once(point))
    }

    /// Append a group of delivered points with batched I/O. Each line is
    /// staged in an internal buffer and the file sees one `write` per flush
    /// boundary instead of two per point, but every observable property of
    /// per-point [`Self::append`] is preserved: the bytes written are
    /// identical, and flushes still land after exactly the same entries
    /// (every `every_n` appends, counted across batch edges), so the
    /// kill/resume valid-prefix guarantee and the `journal.flush` trace
    /// cadence are unchanged.
    pub fn append_batch<'a>(
        &mut self,
        points: impl IntoIterator<Item = &'a PointResult>,
    ) -> Result<()> {
        self.scratch.clear();
        for point in points {
            let line = entry_to_json(self.next_pos, point).to_string_canonical();
            self.scratch.push_str(&line);
            self.scratch.push('\n');
            self.next_pos += 1;
            self.since_flush += 1;
            if self.since_flush >= self.every_n {
                self.out.write_all(self.scratch.as_bytes())?;
                self.out.flush()?;
                self.scratch.clear();
                self.since_flush = 0;
            }
        }
        if !self.scratch.is_empty() {
            self.out.write_all(self.scratch.as_bytes())?;
            self.scratch.clear();
        }
        Ok(())
    }

    /// Final flush at campaign completion.
    pub fn finish(mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::AcceleratorConfig;
    use crate::quant::PeType;

    fn sample_eval(seed: u64) -> Evaluation {
        let config = AcceleratorConfig { rows: 8 + (seed as usize % 8), ..Default::default() };
        crate::dse::evaluate(&config, &crate::dnn::model_for(
            crate::dnn::ModelKind::ResNet20,
            Dataset::Cifar10,
        ), seed)
    }

    #[test]
    fn evaluation_round_trips_bit_for_bit() {
        let eval = sample_eval(7);
        let text = eval.to_json().to_string_canonical();
        let parsed = Evaluation::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, eval);
    }

    #[test]
    fn database_round_trips_and_normalizes_transients() {
        let db = EvalDatabase {
            dataset: Dataset::Cifar10,
            shard: (0, 1),
            strategy: "exhaustive".into(),
            spaces: vec![ModelSpace {
                model_name: "ResNet-20".into(),
                dataset: Dataset::Cifar10,
                evals: vec![sample_eval(1), sample_eval(2)],
            }],
            stats: CampaignStats {
                design_points: 2,
                evaluations: 2,
                wall_seconds: 1.25,
                workers: 4,
            },
        };
        let text = db.to_json().to_string_pretty();
        let parsed = EvalDatabase::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed.dataset, db.dataset);
        assert_eq!(parsed.spaces, db.spaces);
        assert_eq!(parsed.stats.design_points, 2);
        // Transient throughput fields are not persisted.
        assert_eq!(parsed.stats.wall_seconds, 0.0);
        assert_eq!(parsed.stats.workers, 0);
        // Re-serializing the parsed database is byte-identical.
        assert_eq!(parsed.to_json().to_string_pretty(), text);
    }

    #[test]
    fn envelope_rejects_wrong_kind_and_future_schema() {
        let wrong_kind = Json::parse(r#"{"kind": "qadam.pointcache", "schema": 1}"#).unwrap();
        assert_eq!(EvalDatabase::from_json(&wrong_kind).unwrap_err().kind(), "parse_error");
        let future =
            Json::parse(r#"{"kind": "qadam.evaldb", "schema": 99, "dataset": "CIFAR-10"}"#)
                .unwrap();
        assert_eq!(EvalDatabase::from_json(&future).unwrap_err().kind(), "parse_error");
    }

    #[test]
    fn manifest_round_trips_and_detects_mismatch() {
        let manifest = CampaignManifest {
            spec_fingerprint: 0xdead_beef_0123_4567,
            seed: u64::MAX - 3, // exercises > 2^53 (why seeds persist as hex)
            shard: 1,
            num_shards: 4,
            total: 12,
            dataset: "CIFAR-10".into(),
            models: vec!["VGG-16".into(), "ResNet-20".into()],
            strategy: "random:12:9".into(),
            model_axes: crate::arch::ModelAxes::default(),
            campaign_fp: Some(0x0123_4567_89ab_cdef),
        };
        let parsed = CampaignManifest::from_json(&manifest.to_json()).unwrap();
        assert_eq!(parsed, manifest);
        // Trivial axes keep the pre-joint manifest bytes: v3, no
        // model_axes key.
        let text = manifest.to_json().to_string_canonical();
        assert!(text.contains("\"schema\":3"), "{text}");
        assert!(!text.contains("model_axes"), "{text}");
        let mut other = manifest.clone();
        other.seed ^= 1;
        let err = manifest.ensure_matches(&other).unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        assert!(err.to_string().contains("seed"));
        let mut other = manifest.clone();
        other.strategy = "exhaustive".into();
        let err = manifest.ensure_matches(&other).unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        assert!(err.to_string().contains("strategy"));
        // A joint manifest pins its axes at schema v4 and round-trips.
        let mut joint = manifest.clone();
        joint.model_axes =
            crate::arch::ModelAxes { width_mults: vec![0.5, 1.0], depth_mults: vec![1, 2] };
        let text = joint.to_json().to_string_canonical();
        assert!(text.contains("\"schema\":4"), "{text}");
        assert!(text.contains("model_axes"), "{text}");
        let parsed = CampaignManifest::from_json(&joint.to_json()).unwrap();
        assert_eq!(parsed, joint);
        // Axes mismatches are rejected by name.
        let err = manifest.ensure_matches(&joint).unwrap_err();
        assert_eq!(err.kind(), "invalid_config");
        assert!(err.to_string().contains("model axes"), "{err}");
        // A fingerprint-less manifest round-trips without the field, and
        // any fingerprint difference (including present-vs-absent, i.e.
        // an edited or removed spec) rejects the resume.
        let mut bare = manifest.clone();
        bare.campaign_fp = None;
        let parsed = CampaignManifest::from_json(&bare.to_json()).unwrap();
        assert_eq!(parsed, bare);
        for (ours, theirs) in [
            (manifest.clone(), bare.clone()),
            (bare.clone(), manifest.clone()),
            (manifest.clone(), {
                let mut edited = manifest.clone();
                edited.campaign_fp = Some(1);
                edited
            }),
        ] {
            let err = ours.ensure_matches(&theirs).unwrap_err();
            assert_eq!(err.kind(), "invalid_config");
            assert!(err.to_string().contains("spec"), "{err}");
        }
    }

    #[test]
    fn point_key_is_stable_and_input_sensitive() {
        let config = AcceleratorConfig::default();
        let models = vec![crate::dnn::model_for(crate::dnn::ModelKind::ResNet20, Dataset::Cifar10)];
        let key = point_key(&config, 7, &models);
        assert_eq!(key, point_key(&config.clone(), 7, &models));
        assert_ne!(key, point_key(&config, 8, &models), "seed must change the key");
        let mut other = config.clone();
        other.pe = PeType::LightPe1;
        assert_ne!(key, point_key(&other, 7, &models), "pe type must change the key");
        assert_ne!(key, point_key(&config, 7, &[]), "model set must change the key");
    }

    #[test]
    fn config_render_matches_json_tree_byte_for_byte() {
        // The scratch-buffer render must be indistinguishable from the
        // Json-tree canonical render for every config shape — integral
        // fields, fractional clocks/bandwidths, every PE name.
        let mut configs = vec![AcceleratorConfig::default()];
        for pe in PeType::ALL {
            configs.push(AcceleratorConfig {
                pe,
                clock_ghz: 1.337,
                dram_bw_gbps: 25.6,
                rows: 7,
                cols: 13,
                glb_kib: 96,
                ..AcceleratorConfig::default()
            });
        }
        let mut scratch = String::new();
        for config in &configs {
            scratch.clear();
            render_config_canonical(config, &mut scratch);
            assert_eq!(scratch, config.to_json().to_string_canonical());
        }
    }

    #[test]
    fn point_key_with_reused_scratch_matches_point_key() {
        let models =
            vec![crate::dnn::model_for(crate::dnn::ModelKind::ResNet20, Dataset::Cifar10)];
        let mut scratch = String::new();
        for seed in [0u64, 7, 9999] {
            for pe in [PeType::Int16, PeType::LightPe1] {
                let config = AcceleratorConfig { pe, ..AcceleratorConfig::default() };
                assert_eq!(
                    point_key_with(&config, seed, &models, &mut scratch),
                    point_key(&config, seed, &models),
                    "scratch reuse must not change the key"
                );
            }
        }
    }

    #[test]
    fn point_key_sees_layer_geometry_not_just_totals() {
        use crate::dnn::{Layer, Model};
        let custom = |layers| Model { name: "custom".into(), dataset: Dataset::Cifar10, layers };
        // Same name, dataset, layer count, total MACs, and total weights —
        // only the per-layer shape differs. The mapper tiles shapes, so
        // these evaluate differently and must not share a cache entry.
        let a = custom(vec![Layer::fc("fc", 100, 2)]);
        let b = custom(vec![Layer::fc("fc", 50, 4)]);
        assert_eq!(a.total_macs(), b.total_macs());
        assert_eq!(a.total_weights(), b.total_weights());
        let config = AcceleratorConfig::default();
        assert_ne!(point_key(&config, 7, &[a]), point_key(&config, 7, &[b]));
    }

    #[test]
    fn cache_counts_hits_and_misses() {
        let mut cache = PointCache::new();
        let evals = vec![sample_eval(3)];
        assert!(cache.lookup(42).is_none());
        cache.store(42, evals.clone());
        assert_eq!(cache.lookup(42).unwrap(), evals);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert_eq!(cache.total_evaluations(), 1);
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!((cache.hits(), cache.misses()), (0, 0));
    }

    #[test]
    fn cache_round_trips_through_json() {
        let mut cache = PointCache::new();
        cache.store(7, vec![sample_eval(1)]);
        cache.store(u64::MAX, vec![sample_eval(2), sample_eval(3)]);
        let text = cache.to_json().to_string_pretty();
        let parsed = PointCache::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed.get(7).unwrap(), cache.get(7).unwrap());
        assert_eq!(parsed.get(u64::MAX).unwrap(), cache.get(u64::MAX).unwrap());
        assert_eq!((parsed.hits(), parsed.misses()), (0, 0));
    }
}
