//! Binary columnar `EvalDatabase` format (`qadam.qdb`).
//!
//! Canonical JSON (`EvalDatabase::save`/`load`) stays the diffable interchange
//! format; `.qdb` is the campaign-scale companion for million-point sweeps
//! where parsing and materializing JSON dominates wall time. The layout is a
//! fixed little-endian header, a deduplicated string table, a per-space
//! directory, column-major metric/config arrays, and a trailing FNV-1a
//! integrity footer:
//!
//! ```text
//! offset  size  field
//! ------  ----  -----------------------------------------------
//!      0     8  magic "QADAMQDB"
//!      8     4  schema version (u32, currently 1)
//!     12     4  shard index (u32)
//!     16     4  num shards (u32)
//!     20     4  num spaces (u32)
//!     24     4  num strings (u32)
//!     28     4  dataset string index (u32)
//!     32     4  strategy string index (u32)
//!     36     4  reserved (u32, 0)
//!     40     8  design points (u64)
//!     48     8  evaluations (u64)
//!     56     8  content fingerprint (u64, FNV-1a over identity)
//!     64     —  string table: per string u32 length + UTF-8 bytes
//!      —     —  directory: per space (name idx u32, dataset idx u32, rows u64)
//!      —     —  per-space column data (see COLUMN_ELEM_BYTES)
//!    end     8  FNV-1a 64 over all preceding bytes
//! ```
//!
//! Within each space, columns are stored back to back in a fixed order:
//! eight f64 metric columns (`area_mm2`, `clock_ghz`, `latency_ms`,
//! `inf_per_s`, `perf_per_area`, `energy_uj`, `dram_energy_uj`,
//! `utilization`), two f64 config columns (`clock_ghz`, `dram_bw_gbps`),
//! six u32 config columns (`rows`, `cols`, `glb_kib`, `ifmap_spad`,
//! `filter_spad`, `psum_spad`), and one u32 PE column holding a string-table
//! index. f64 values are stored via `to_bits` so the JSON→qdb→JSON round trip
//! is bit-exact.
//!
//! [`QdbWriter`] streams appends without ever holding a whole campaign in
//! RAM: per-space row counts are fixed at [`QdbWriter::create`] time, so every
//! column's byte range is known up front, and appends buffer into fixed-size
//! per-column chunks that are flushed with positioned writes into a
//! preallocated temp file. `finish` re-reads the file sequentially to compute
//! the footer hash, then renames the temp file into place (same atomic
//! discipline as the JSON artifact writers).

use std::collections::BTreeMap;
use std::fs;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::arch::{AcceleratorConfig, ScratchpadCfg};
use crate::dnn::Dataset;
use crate::dse::Evaluation;
use crate::explore::db::{CampaignStats, EvalDatabase, ModelSpace};
use crate::quant::PeType;
use crate::util::Fnv64;
use crate::{Error, Result};

/// Magic bytes at the start of every `.qdb` file.
pub const QDB_MAGIC: [u8; 8] = *b"QADAMQDB";
/// Schema version of the qdb container. Versioned independently of the JSON
/// envelope lineage (`qadam.evaldb`): the binary layout evolves on its own.
pub const QDB_SCHEMA_VERSION: u32 = 1;

/// Fixed header length in bytes.
const HEADER_BYTES: u64 = 64;
/// Bytes per evaluation row across all columns (10 f64 + 7 u32).
const ROW_BYTES: u64 = 10 * 8 + 7 * 4;
/// Rows buffered per column before a positioned flush.
const CHUNK_ROWS: usize = 1024;
/// Number of columns per space.
const NUM_COLUMNS: usize = 17;

/// Element width of each column, in declaration order: eight metric f64s, two
/// config f64s, six config u32s, one PE string-index u32.
const COLUMN_ELEM_BYTES: [u8; NUM_COLUMNS] = [8, 8, 8, 8, 8, 8, 8, 8, 8, 8, 4, 4, 4, 4, 4, 4, 4];

fn parse_dataset(name: &str, what: &str) -> Result<Dataset> {
    Dataset::parse(name)
        .ok_or_else(|| Error::ParseError(format!("qdb {what} names unknown dataset '{name}'")))
}

/// Immutable plan for a single space inside a [`QdbPlan`].
#[derive(Debug, Clone)]
pub struct QdbSpacePlan {
    /// Model name (may carry an `@variant` suffix for joint campaigns).
    pub model_name: String,
    /// Dataset the space's evaluations ran against.
    pub dataset: Dataset,
    /// Exact number of evaluations that will be appended for this space.
    pub rows: usize,
}

/// Everything a [`QdbWriter`] must know before the first append: the file
/// layout is fully determined by the plan, which is what lets appends stream
/// without buffering the campaign.
#[derive(Debug, Clone)]
pub struct QdbPlan {
    /// Campaign-level dataset designator.
    pub dataset: Dataset,
    /// `(shard, num_shards)` designator, same semantics as [`EvalDatabase`].
    pub shard: (usize, usize),
    /// Selection strategy label (`"exhaustive"`, `"random"`, ...).
    pub strategy: String,
    /// Per-space plans, in output order.
    pub spaces: Vec<QdbSpacePlan>,
    /// Campaign stat: number of design points visited.
    pub design_points: usize,
    /// Campaign stat: total evaluations (must equal the sum of space rows).
    pub evaluations: usize,
}

impl QdbPlan {
    /// Derive a plan from a fully materialized database (the convert path).
    pub fn from_database(db: &EvalDatabase) -> Self {
        QdbPlan {
            dataset: db.dataset,
            shard: db.shard,
            strategy: db.strategy.clone(),
            spaces: db
                .spaces
                .iter()
                .map(|space| QdbSpacePlan {
                    model_name: space.model_name.clone(),
                    dataset: space.dataset,
                    rows: space.evals.len(),
                })
                .collect(),
            design_points: db.stats.design_points,
            evaluations: db.stats.evaluations,
        }
    }

    fn validate(&self) -> Result<()> {
        if self.shard.1 == 0 || self.shard.0 >= self.shard.1 {
            return Err(Error::InvalidConfig(format!(
                "qdb plan has invalid shard designator {}/{}",
                self.shard.0, self.shard.1
            )));
        }
        let total: usize = self.spaces.iter().map(|space| space.rows).sum();
        if total != self.evaluations {
            return Err(Error::InvalidConfig(format!(
                "qdb plan declares {} evaluations but space rows sum to {total}",
                self.evaluations
            )));
        }
        Ok(())
    }
}

/// Deterministic identity fingerprint over the plan: campaign designators and
/// per-space shapes, each field length-prefixed so adjacent fields cannot
/// alias. Stored in the header and re-verified on load.
fn plan_fingerprint(plan: &QdbPlan) -> u64 {
    let mut hash = Fnv64::new();
    let mut feed = |bytes: &[u8]| {
        hash.update(&(bytes.len() as u64).to_le_bytes()).update(bytes);
    };
    feed(plan.dataset.name().as_bytes());
    feed(&(plan.shard.0 as u64).to_le_bytes());
    feed(&(plan.shard.1 as u64).to_le_bytes());
    feed(plan.strategy.as_bytes());
    feed(&(plan.design_points as u64).to_le_bytes());
    feed(&(plan.evaluations as u64).to_le_bytes());
    for space in &plan.spaces {
        feed(space.model_name.as_bytes());
        feed(space.dataset.name().as_bytes());
        feed(&(space.rows as u64).to_le_bytes());
    }
    hash.finish()
}

/// Deduplicating string table with deterministic first-insertion order.
struct StringTable {
    strings: Vec<String>,
    index: BTreeMap<String, u32>,
}

impl StringTable {
    fn new() -> Self {
        StringTable { strings: Vec::new(), index: BTreeMap::new() }
    }

    fn intern(&mut self, text: &str) -> u32 {
        if let Some(&idx) = self.index.get(text) {
            return idx;
        }
        let idx = self.strings.len() as u32;
        self.strings.push(text.to_string());
        self.index.insert(text.to_string(), idx);
        idx
    }

    fn encoded(&self) -> Vec<u8> {
        let mut bytes = Vec::new();
        for text in &self.strings {
            bytes.extend_from_slice(&(text.len() as u32).to_le_bytes());
            bytes.extend_from_slice(text.as_bytes());
        }
        bytes
    }
}

/// One buffered column of the file being written.
struct ColumnState {
    /// Absolute byte offset of this column's first element.
    base: u64,
    /// Element width in bytes (4 or 8).
    elem: u8,
    /// Rows already flushed to the file.
    flushed_rows: u64,
    /// Pending encoded elements, at most `CHUNK_ROWS * elem` bytes.
    buf: Vec<u8>,
}

fn flush_column(file: &mut fs::File, col: &mut ColumnState) -> Result<()> {
    if col.buf.is_empty() {
        return Ok(());
    }
    let offset = col.base + col.flushed_rows * u64::from(col.elem);
    file.seek(SeekFrom::Start(offset))?;
    file.write_all(&col.buf)?;
    col.flushed_rows += (col.buf.len() / usize::from(col.elem)) as u64;
    col.buf.clear();
    Ok(())
}

struct SpaceState {
    rows: u64,
    appended: u64,
    cols: Vec<ColumnState>,
}

/// Streaming `.qdb` writer: appends one evaluation at a time into a
/// preallocated temp file and finalizes with an integrity footer plus an
/// atomic rename. Buffered memory is bounded by
/// `spaces × NUM_COLUMNS × CHUNK_ROWS × 8` bytes regardless of campaign size.
pub struct QdbWriter {
    file: fs::File,
    final_path: PathBuf,
    tmp_path: PathBuf,
    spaces: Vec<SpaceState>,
    pe_indices: [u32; PeType::ALL.len()],
    data_end: u64,
    finished: bool,
}

impl QdbWriter {
    /// Create the temp file, write the header/string-table/directory prefix,
    /// and preallocate the column region. Fails with
    /// [`Error::InvalidConfig`] on an inconsistent plan.
    pub fn create(path: &Path, plan: &QdbPlan) -> Result<Self> {
        plan.validate()?;
        let mut strings = StringTable::new();
        let dataset_idx = strings.intern(plan.dataset.name());
        let strategy_idx = strings.intern(&plan.strategy);
        let space_indices: Vec<(u32, u32)> = plan
            .spaces
            .iter()
            .map(|space| (strings.intern(&space.model_name), strings.intern(space.dataset.name())))
            .collect();
        // PE names are interned up front: the set is a closed enum, and a
        // streaming writer cannot grow the table after the prefix is written.
        let mut pe_indices = [0u32; PeType::ALL.len()];
        for (slot, pe) in pe_indices.iter_mut().zip(PeType::ALL) {
            *slot = strings.intern(pe.name());
        }
        let string_bytes = strings.encoded();
        let dir_bytes_len = plan.spaces.len() as u64 * 16;
        let data_start = HEADER_BYTES + string_bytes.len() as u64 + dir_bytes_len;

        let mut header = Vec::with_capacity(HEADER_BYTES as usize);
        header.extend_from_slice(&QDB_MAGIC);
        header.extend_from_slice(&QDB_SCHEMA_VERSION.to_le_bytes());
        header.extend_from_slice(&u32_of(plan.shard.0, "shard")?.to_le_bytes());
        header.extend_from_slice(&u32_of(plan.shard.1, "num_shards")?.to_le_bytes());
        header.extend_from_slice(&u32_of(plan.spaces.len(), "num_spaces")?.to_le_bytes());
        header.extend_from_slice(&(strings.strings.len() as u32).to_le_bytes());
        header.extend_from_slice(&dataset_idx.to_le_bytes());
        header.extend_from_slice(&strategy_idx.to_le_bytes());
        header.extend_from_slice(&0u32.to_le_bytes());
        header.extend_from_slice(&(plan.design_points as u64).to_le_bytes());
        header.extend_from_slice(&(plan.evaluations as u64).to_le_bytes());
        header.extend_from_slice(&plan_fingerprint(plan).to_le_bytes());
        debug_assert_eq!(header.len() as u64, HEADER_BYTES);

        let mut dir_bytes = Vec::with_capacity(dir_bytes_len as usize);
        let mut spaces = Vec::with_capacity(plan.spaces.len());
        let mut cursor = data_start;
        for (space, &(name_idx, ds_idx)) in plan.spaces.iter().zip(&space_indices) {
            dir_bytes.extend_from_slice(&name_idx.to_le_bytes());
            dir_bytes.extend_from_slice(&ds_idx.to_le_bytes());
            dir_bytes.extend_from_slice(&(space.rows as u64).to_le_bytes());
            let mut cols = Vec::with_capacity(NUM_COLUMNS);
            for &elem in &COLUMN_ELEM_BYTES {
                cols.push(ColumnState { base: cursor, elem, flushed_rows: 0, buf: Vec::new() });
                cursor = cursor
                    .checked_add(space.rows as u64 * u64::from(elem))
                    .ok_or_else(|| {
                        Error::InvalidConfig("qdb plan overflows the addressable file size".into())
                    })?;
            }
            spaces.push(SpaceState { rows: space.rows as u64, appended: 0, cols });
        }
        let data_end = cursor;

        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                fs::create_dir_all(parent)?;
            }
        }
        let tmp_path = tmp_sibling(path);
        let mut file = fs::File::create(&tmp_path)?;
        file.write_all(&header)?;
        file.write_all(&string_bytes)?;
        file.write_all(&dir_bytes)?;
        file.set_len(data_end)?;
        Ok(QdbWriter {
            file,
            final_path: path.to_path_buf(),
            tmp_path,
            spaces,
            pe_indices,
            data_end,
            finished: false,
        })
    }

    /// Append one evaluation to the given space. Errors with
    /// [`Error::InvalidConfig`] when the space index is out of range, the
    /// space is already full, or a config field exceeds the u32 column width.
    pub fn append(&mut self, space: usize, eval: &Evaluation) -> Result<()> {
        let num_spaces = self.spaces.len();
        let state = self.spaces.get_mut(space).ok_or_else(|| {
            Error::InvalidConfig(format!(
                "qdb append to space {space} but the plan declares {num_spaces} space(s)"
            ))
        })?;
        if state.appended >= state.rows {
            return Err(Error::InvalidConfig(format!(
                "qdb space {space} is full: plan declared {} row(s)",
                state.rows
            )));
        }
        let cfg = &eval.config;
        let f64s = [
            eval.area_mm2,
            eval.clock_ghz,
            eval.latency_ms,
            eval.inf_per_s,
            eval.perf_per_area,
            eval.energy_uj,
            eval.dram_energy_uj,
            eval.utilization,
            cfg.clock_ghz,
            cfg.dram_bw_gbps,
        ];
        let u32s = [
            u32_of(cfg.rows, "rows")?,
            u32_of(cfg.cols, "cols")?,
            u32_of(cfg.glb_kib, "glb_kib")?,
            u32_of(cfg.spad.ifmap_entries, "ifmap_spad")?,
            u32_of(cfg.spad.filter_entries, "filter_spad")?,
            u32_of(cfg.spad.psum_entries, "psum_spad")?,
            self.pe_indices[cfg.pe as usize],
        ];
        for (col, value) in state.cols.iter_mut().take(f64s.len()).zip(f64s) {
            col.buf.extend_from_slice(&value.to_bits().to_le_bytes());
        }
        for (col, value) in state.cols.iter_mut().skip(f64s.len()).zip(u32s) {
            col.buf.extend_from_slice(&value.to_le_bytes());
        }
        state.appended += 1;
        for col in &mut state.cols {
            if col.buf.len() >= CHUNK_ROWS * usize::from(col.elem) {
                flush_column(&mut self.file, col)?;
            }
        }
        Ok(())
    }

    /// Flush residual buffers, verify every space got exactly its planned row
    /// count, compute and append the footer hash, and atomically rename the
    /// temp file into place.
    pub fn finish(mut self) -> Result<()> {
        for (idx, state) in self.spaces.iter_mut().enumerate() {
            if state.appended != state.rows {
                return Err(Error::InvalidConfig(format!(
                    "qdb space {idx} got {} of {} planned row(s)",
                    state.appended, state.rows
                )));
            }
            for col in &mut state.cols {
                flush_column(&mut self.file, col)?;
            }
        }
        self.file.flush()?;
        // Positioned writes landed out of order, so the footer hash is
        // computed with one sequential re-read of the finished byte range.
        self.file.seek(SeekFrom::Start(0))?;
        let mut hash = Fnv64::new();
        let mut remaining = self.data_end;
        let mut chunk = vec![0u8; 64 * 1024];
        while remaining > 0 {
            let want = remaining.min(chunk.len() as u64) as usize;
            self.file.read_exact(&mut chunk[..want])?;
            hash.update(&chunk[..want]);
            remaining -= want as u64;
        }
        self.file.seek(SeekFrom::Start(self.data_end))?;
        self.file.write_all(&hash.finish().to_le_bytes())?;
        self.file.flush()?;
        self.file.sync_all()?;
        fs::rename(&self.tmp_path, &self.final_path)?;
        self.finished = true;
        Ok(())
    }
}

impl Drop for QdbWriter {
    fn drop(&mut self) {
        if !self.finished {
            let _ = fs::remove_file(&self.tmp_path);
        }
    }
}

fn tmp_sibling(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

fn u32_of(value: usize, field: &str) -> Result<u32> {
    u32::try_from(value).map_err(|_| {
        Error::InvalidConfig(format!("qdb field {field} value {value} exceeds u32 range"))
    })
}

/// Bounds-checked little-endian reader over a loaded byte buffer; every
/// overrun becomes a typed [`Error::ParseError`] naming what was being read.
struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        Cursor { bytes, pos: 0 }
    }

    fn take(&mut self, len: usize, what: &str) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(len).ok_or_else(|| truncated(what, self.pos))?;
        if end > self.bytes.len() {
            return Err(truncated(what, self.pos));
        }
        let slice = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(slice)
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let bytes = self.take(4, what)?;
        let mut buf = [0u8; 4];
        buf.copy_from_slice(bytes);
        Ok(u32::from_le_bytes(buf))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let bytes = self.take(8, what)?;
        let mut buf = [0u8; 8];
        buf.copy_from_slice(bytes);
        Ok(u64::from_le_bytes(buf))
    }
}

fn truncated(what: &str, pos: usize) -> Error {
    Error::ParseError(format!("qdb truncated reading {what} at byte {pos}"))
}

/// True when the buffer starts with the qdb magic. Used to sniff the format
/// before committing to a binary or JSON parse.
pub fn is_qdb_bytes(bytes: &[u8]) -> bool {
    bytes.len() >= QDB_MAGIC.len() && bytes[..QDB_MAGIC.len()] == QDB_MAGIC
}

/// Check only the magic and schema version of a qdb buffer — the cheap
/// envelope probe used by `qadam lint` (Q011), mirroring
/// `check_envelope_exact` for the JSON lineages.
pub fn check_qdb_envelope(bytes: &[u8]) -> Result<()> {
    let mut cur = Cursor::new(bytes);
    let magic = cur.take(QDB_MAGIC.len(), "magic")?;
    if magic != QDB_MAGIC {
        return Err(Error::ParseError("not a qadam.qdb file (bad magic)".into()));
    }
    let schema = cur.u32("schema version")?;
    if schema != QDB_SCHEMA_VERSION {
        return Err(Error::ParseError(format!(
            "qadam.qdb schema version {schema} is not supported (expected {QDB_SCHEMA_VERSION})"
        )));
    }
    Ok(())
}

struct ParsedHeader {
    shard: (usize, usize),
    num_spaces: usize,
    num_strings: usize,
    dataset_idx: u32,
    strategy_idx: u32,
    design_points: u64,
    evaluations: u64,
    fingerprint: u64,
}

fn parse_header(cur: &mut Cursor<'_>) -> Result<ParsedHeader> {
    check_qdb_envelope(cur.bytes)?;
    cur.pos = QDB_MAGIC.len() + 4; // past magic + schema, both validated above
    let shard = cur.u32("shard index")? as usize;
    let num_shards = cur.u32("num shards")? as usize;
    let num_spaces = cur.u32("num spaces")? as usize;
    let num_strings = cur.u32("num strings")? as usize;
    let dataset_idx = cur.u32("dataset string index")?;
    let strategy_idx = cur.u32("strategy string index")?;
    let reserved = cur.u32("reserved field")?;
    if reserved != 0 {
        return Err(Error::ParseError(format!(
            "qdb reserved header field is {reserved}, expected 0"
        )));
    }
    let design_points = cur.u64("design points")?;
    let evaluations = cur.u64("evaluations")?;
    let fingerprint = cur.u64("fingerprint")?;
    if num_shards == 0 || shard >= num_shards {
        return Err(Error::ParseError(format!(
            "database has invalid shard designator {shard}/{num_shards}"
        )));
    }
    Ok(ParsedHeader {
        shard: (shard, num_shards),
        num_spaces,
        num_strings,
        dataset_idx,
        strategy_idx,
        design_points,
        evaluations,
        fingerprint,
    })
}

fn parse_strings(cur: &mut Cursor<'_>, count: usize) -> Result<Vec<String>> {
    // Each string costs at least 4 bytes, so a corrupt count cannot force a
    // huge up-front allocation past the buffer it must be decoded from.
    let mut strings = Vec::with_capacity(count.min(cur.bytes.len() / 4 + 1));
    for idx in 0..count {
        let len = cur.u32(&format!("string {idx} length"))? as usize;
        let bytes = cur.take(len, &format!("string {idx} bytes"))?;
        let text = std::str::from_utf8(bytes)
            .map_err(|_| Error::ParseError(format!("qdb string {idx} is not valid UTF-8")))?;
        strings.push(text.to_string());
    }
    Ok(strings)
}

fn string_at<'a>(strings: &'a [String], idx: u32, what: &str) -> Result<&'a str> {
    strings.get(idx as usize).map(String::as_str).ok_or_else(|| {
        Error::ParseError(format!(
            "qdb {what} string index {idx} out of range ({} strings)",
            strings.len()
        ))
    })
}

struct SpaceDir {
    name_idx: u32,
    dataset_idx: u32,
    rows: usize,
}

/// Parsed and fully verified qdb metadata, as reported by `qadam db inspect`.
#[derive(Debug)]
pub struct QdbInfo {
    /// Schema version from the header.
    pub schema: u32,
    /// Identity fingerprint from the header.
    pub fingerprint: u64,
    /// Campaign dataset designator.
    pub dataset: Dataset,
    /// `(shard, num_shards)` designator.
    pub shard: (usize, usize),
    /// Selection strategy label.
    pub strategy: String,
    /// Number of design points visited by the campaign.
    pub design_points: usize,
    /// Total evaluations stored.
    pub evaluations: usize,
    /// Per-space `(model_name, rows)` pairs, in file order.
    pub spaces: Vec<(String, usize)>,
    /// Total file size in bytes.
    pub bytes: usize,
}

struct Parsed {
    header: ParsedHeader,
    strings: Vec<String>,
    dirs: Vec<SpaceDir>,
    data_start: usize,
}

/// Structural + integrity parse shared by `load_qdb` and `inspect_qdb`:
/// validates magic, schema, exact file length, footer hash, and fingerprint
/// before any column is decoded.
fn parse_verified(bytes: &[u8]) -> Result<Parsed> {
    let mut cur = Cursor::new(bytes);
    let header = parse_header(&mut cur)?;
    let strings = parse_strings(&mut cur, header.num_strings)?;
    let mut dirs = Vec::with_capacity(header.num_spaces.min(bytes.len() / 16 + 1));
    for idx in 0..header.num_spaces {
        let name_idx = cur.u32(&format!("space {idx} name index"))?;
        let dataset_idx = cur.u32(&format!("space {idx} dataset index"))?;
        let rows = cur.u64(&format!("space {idx} row count"))?;
        let rows = usize::try_from(rows).map_err(|_| {
            Error::ParseError(format!("qdb space {idx} row count {rows} exceeds usize"))
        })?;
        dirs.push(SpaceDir { name_idx, dataset_idx, rows });
    }
    let data_start = cur.pos;
    let mut data_end = data_start as u64;
    for dir in &dirs {
        data_end = data_end
            .checked_add(dir.rows as u64 * ROW_BYTES)
            .ok_or_else(|| Error::ParseError("qdb directory overflows file size".into()))?;
    }
    let expected_total = data_end
        .checked_add(8)
        .ok_or_else(|| Error::ParseError("qdb directory overflows file size".into()))?;
    match (bytes.len() as u64).cmp(&expected_total) {
        std::cmp::Ordering::Less => {
            return Err(Error::ParseError(format!(
                "qdb truncated: {} byte(s) but the directory requires {expected_total}",
                bytes.len()
            )));
        }
        std::cmp::Ordering::Greater => {
            return Err(Error::ParseError(format!(
                "qdb has {} trailing byte(s) past the footer",
                bytes.len() as u64 - expected_total
            )));
        }
        std::cmp::Ordering::Equal => {}
    }
    let stored = {
        let mut buf = [0u8; 8];
        buf.copy_from_slice(&bytes[data_end as usize..]);
        u64::from_le_bytes(buf)
    };
    let computed = crate::util::fnv1a_64(&bytes[..data_end as usize]);
    if stored != computed {
        return Err(Error::ParseError(format!(
            "qdb integrity footer mismatch: stored {stored:#018x}, computed {computed:#018x}"
        )));
    }
    // Recompute the identity fingerprint from the decoded metadata.
    let dataset = parse_dataset(string_at(&strings, header.dataset_idx, "dataset")?, "header")?;
    let strategy = string_at(&strings, header.strategy_idx, "strategy")?.to_string();
    let plan = QdbPlan {
        dataset,
        shard: header.shard,
        strategy,
        spaces: dirs
            .iter()
            .enumerate()
            .map(|(idx, dir)| {
                Ok(QdbSpacePlan {
                    model_name: string_at(&strings, dir.name_idx, "space name")?.to_string(),
                    dataset: parse_dataset(
                        string_at(&strings, dir.dataset_idx, "space dataset")?,
                        &format!("space {idx}"),
                    )?,
                    rows: dir.rows,
                })
            })
            .collect::<Result<Vec<_>>>()?,
        design_points: usize::try_from(header.design_points)
            .map_err(|_| Error::ParseError("qdb design point count exceeds usize".into()))?,
        evaluations: usize::try_from(header.evaluations)
            .map_err(|_| Error::ParseError("qdb evaluation count exceeds usize".into()))?,
    };
    let recomputed = plan_fingerprint(&plan);
    if recomputed != header.fingerprint {
        return Err(Error::ParseError(format!(
            "qdb fingerprint mismatch: header {:#018x}, recomputed {recomputed:#018x}",
            header.fingerprint
        )));
    }
    let total_rows: usize = dirs.iter().map(|dir| dir.rows).sum();
    if total_rows as u64 != header.evaluations {
        return Err(Error::ParseError(format!(
            "qdb header declares {} evaluation(s) but spaces hold {total_rows}",
            header.evaluations
        )));
    }
    Ok(Parsed { header, strings, dirs, data_start })
}

fn f64_column(bytes: &[u8], base: usize, row: usize) -> f64 {
    let start = base + row * 8;
    let mut buf = [0u8; 8];
    buf.copy_from_slice(&bytes[start..start + 8]);
    f64::from_bits(u64::from_le_bytes(buf))
}

fn u32_column(bytes: &[u8], base: usize, row: usize) -> u32 {
    let start = base + row * 4;
    let mut buf = [0u8; 4];
    buf.copy_from_slice(&bytes[start..start + 4]);
    u32::from_le_bytes(buf)
}

fn decode_space(
    bytes: &[u8],
    strings: &[String],
    dir: &SpaceDir,
    base: usize,
    space_idx: usize,
) -> Result<ModelSpace> {
    let rows = dir.rows;
    // Column base offsets within this space, in declaration order.
    let mut bases = [0usize; NUM_COLUMNS];
    let mut cursor = base;
    for (slot, &elem) in bases.iter_mut().zip(&COLUMN_ELEM_BYTES) {
        *slot = cursor;
        cursor += rows * usize::from(elem);
    }
    let mut evals = Vec::with_capacity(rows);
    for row in 0..rows {
        let metric = |col: usize| f64_column(bytes, bases[col], row);
        let digit = |col: usize| u32_column(bytes, bases[col], row) as usize;
        let pe_idx = u32_column(bytes, bases[16], row);
        let pe_name = string_at(strings, pe_idx, &format!("space {space_idx} pe"))?;
        let pe = PeType::parse(pe_name).ok_or_else(|| {
            Error::ParseError(format!(
                "qdb space {space_idx} row {row} names unknown PE type '{pe_name}'"
            ))
        })?;
        let config = AcceleratorConfig {
            pe,
            rows: digit(10),
            cols: digit(11),
            spad: ScratchpadCfg {
                ifmap_entries: digit(13),
                filter_entries: digit(14),
                psum_entries: digit(15),
            },
            glb_kib: digit(12),
            dram_bw_gbps: metric(9),
            clock_ghz: metric(8),
        };
        config.validate().map_err(|err| {
            Error::ParseError(format!(
                "qdb space {space_idx} row {row} holds an invalid config: {err}"
            ))
        })?;
        evals.push(Evaluation {
            config,
            area_mm2: metric(0),
            clock_ghz: metric(1),
            latency_ms: metric(2),
            inf_per_s: metric(3),
            perf_per_area: metric(4),
            energy_uj: metric(5),
            dram_energy_uj: metric(6),
            utilization: metric(7),
        });
    }
    Ok(ModelSpace {
        model_name: string_at(strings, dir.name_idx, "space name")?.to_string(),
        dataset: parse_dataset(
            string_at(strings, dir.dataset_idx, "space dataset")?,
            &format!("space {space_idx}"),
        )?,
        evals,
    })
}

/// Parse and fully verify a qdb file's metadata without decoding any rows.
pub fn inspect_qdb(path: &Path) -> Result<QdbInfo> {
    let bytes = fs::read(path)?;
    let parsed = parse_verified(&bytes)
        .map_err(|err| Error::ParseError(format!("{}: {err}", path.display())))?;
    let dataset =
        parse_dataset(string_at(&parsed.strings, parsed.header.dataset_idx, "dataset")?, "header")?;
    let strategy =
        string_at(&parsed.strings, parsed.header.strategy_idx, "strategy")?.to_string();
    let spaces = parsed
        .dirs
        .iter()
        .map(|dir| {
            Ok((string_at(&parsed.strings, dir.name_idx, "space name")?.to_string(), dir.rows))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(QdbInfo {
        schema: QDB_SCHEMA_VERSION,
        fingerprint: parsed.header.fingerprint,
        dataset,
        shard: parsed.header.shard,
        strategy,
        design_points: parsed.header.design_points as usize,
        evaluations: parsed.header.evaluations as usize,
        spaces,
        bytes: bytes.len(),
    })
}

impl EvalDatabase {
    /// Write this database in the binary columnar `.qdb` format. Implemented
    /// via [`QdbWriter`], so convert-path and stream-path files are
    /// byte-identical for the same content.
    pub fn save_qdb(&self, path: &Path) -> Result<()> {
        let plan = QdbPlan::from_database(self);
        let mut writer = QdbWriter::create(path, &plan)?;
        for (idx, space) in self.spaces.iter().enumerate() {
            for eval in &space.evals {
                writer.append(idx, eval)?;
            }
        }
        writer.finish()
    }

    /// Load a `.qdb` file, verifying the magic, schema, exact length, footer
    /// hash, and identity fingerprint before decoding any column.
    ///
    /// The transient `wall_seconds`/`workers` stats are not carried by the
    /// format (mirroring the JSON serializer, which drops them so identical
    /// campaigns always produce byte-identical files).
    pub fn load_qdb(path: &Path) -> Result<Self> {
        let bytes = fs::read(path)?;
        let parsed = parse_verified(&bytes)
            .map_err(|err| Error::ParseError(format!("{}: {err}", path.display())))?;
        let dataset = parse_dataset(
            string_at(&parsed.strings, parsed.header.dataset_idx, "dataset")?,
            "header",
        )?;
        let strategy =
            string_at(&parsed.strings, parsed.header.strategy_idx, "strategy")?.to_string();
        let mut spaces = Vec::with_capacity(parsed.dirs.len());
        let mut base = parsed.data_start;
        for (idx, dir) in parsed.dirs.iter().enumerate() {
            let space = decode_space(&bytes, &parsed.strings, dir, base, idx)
                .map_err(|err| Error::ParseError(format!("{}: {err}", path.display())))?;
            base += dir.rows * ROW_BYTES as usize;
            spaces.push(space);
        }
        Ok(EvalDatabase {
            dataset,
            shard: parsed.header.shard,
            strategy,
            spaces,
            stats: CampaignStats {
                design_points: parsed.header.design_points as usize,
                evaluations: parsed.header.evaluations as usize,
                wall_seconds: 0.0,
                workers: 0,
            },
        })
    }

    /// Load a database from either format, sniffing the qdb magic first and
    /// falling back to canonical JSON.
    pub fn load_any(path: &Path) -> Result<Self> {
        let mut probe = [0u8; QDB_MAGIC.len()];
        let is_qdb = fs::File::open(path)
            .and_then(|mut file| file.read_exact(&mut probe))
            .map(|()| probe == QDB_MAGIC)
            .unwrap_or(false);
        if is_qdb {
            EvalDatabase::load_qdb(path)
        } else {
            EvalDatabase::load(path)
        }
    }

    /// Save in the format implied by the path extension: `.qdb` → binary
    /// columnar, anything else → canonical JSON.
    pub fn save_auto(&self, path: &Path) -> Result<()> {
        if path.extension().is_some_and(|ext| ext == "qdb") {
            self.save_qdb(path)
        } else {
            self.save(path)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::ModelKind;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("qadam_qdb_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_eval(seed: u64) -> Evaluation {
        let config = AcceleratorConfig { rows: 8 + (seed as usize % 8), ..Default::default() };
        crate::dse::evaluate(
            &config,
            &crate::dnn::model_for(ModelKind::ResNet20, Dataset::Cifar10),
            seed,
        )
    }

    fn sample_db(per_space: usize) -> EvalDatabase {
        let spaces = vec![
            ModelSpace {
                model_name: "ResNet-20".into(),
                dataset: Dataset::Cifar10,
                evals: (0..per_space).map(|i| sample_eval(i as u64)).collect(),
            },
            ModelSpace {
                model_name: "ResNet-20@w0.5d2".into(),
                dataset: Dataset::Cifar10,
                evals: (0..per_space).map(|i| sample_eval(100 + i as u64)).collect(),
            },
        ];
        EvalDatabase {
            dataset: Dataset::Cifar10,
            shard: (0, 1),
            strategy: "exhaustive".into(),
            spaces,
            stats: CampaignStats {
                design_points: per_space,
                evaluations: per_space * 2,
                wall_seconds: 0.0,
                workers: 0,
            },
        }
    }

    #[test]
    fn roundtrip_is_json_byte_identical() {
        let dir = temp_dir("roundtrip");
        let db = sample_db(5);
        let json_path = dir.join("db.json");
        let qdb_path = dir.join("db.qdb");
        db.save(&json_path).unwrap();
        db.save_qdb(&qdb_path).unwrap();
        let reloaded = EvalDatabase::load_qdb(&qdb_path).unwrap();
        let rt_path = dir.join("rt.json");
        reloaded.save(&rt_path).unwrap();
        // The JSON serializer drops the transient wall_seconds/workers stats,
        // so JSON → qdb → JSON must reproduce the original file byte for byte.
        let original = fs::read_to_string(&json_path).unwrap();
        let round = fs::read_to_string(&rt_path).unwrap();
        assert_eq!(original, round);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn odd_f64_values_survive_bit_exact() {
        let dir = temp_dir("bits");
        let mut db = sample_db(1);
        {
            let eval = &mut db.spaces[0].evals[0];
            eval.energy_uj = f64::from_bits(0x3FF0_0000_0000_0001); // 1.0 + 1 ulp
            eval.latency_ms = 1.0e-300;
            eval.utilization = 0.1 + 0.2; // non-terminating in decimal
        }
        let bits_before: Vec<u64> = {
            let eval = &db.spaces[0].evals[0];
            [eval.energy_uj, eval.latency_ms, eval.utilization]
                .iter()
                .map(|v| v.to_bits())
                .collect()
        };
        let path = dir.join("bits.qdb");
        db.save_qdb(&path).unwrap();
        let loaded = EvalDatabase::load_qdb(&path).unwrap();
        let eval = &loaded.spaces[0].evals[0];
        let bits_after: Vec<u64> = [eval.energy_uj, eval.latency_ms, eval.utilization]
            .iter()
            .map(|v| v.to_bits())
            .collect();
        assert_eq!(bits_before, bits_after);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn inspect_reports_shapes() {
        let dir = temp_dir("inspect");
        let db = sample_db(3);
        let path = dir.join("db.qdb");
        db.save_qdb(&path).unwrap();
        let info = inspect_qdb(&path).unwrap();
        assert_eq!(info.schema, QDB_SCHEMA_VERSION);
        assert_eq!(info.evaluations, 6);
        assert_eq!(info.design_points, 3);
        assert_eq!(info.spaces.len(), 2);
        assert_eq!(info.spaces[0], ("ResNet-20".to_string(), 3));
        assert_eq!(info.spaces[1], ("ResNet-20@w0.5d2".to_string(), 3));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_any_sniffs_both_formats() {
        let dir = temp_dir("sniff");
        let db = sample_db(2);
        let json_path = dir.join("db.json");
        let qdb_path = dir.join("db.qdb");
        db.save(&json_path).unwrap();
        db.save_qdb(&qdb_path).unwrap();
        let a = EvalDatabase::load_any(&json_path).unwrap();
        let b = EvalDatabase::load_any(&qdb_path).unwrap();
        assert_eq!(a, b);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_auto_picks_format_by_extension() {
        let dir = temp_dir("auto");
        let db = sample_db(1);
        let qdb_path = dir.join("out.qdb");
        let json_path = dir.join("out.json");
        db.save_auto(&qdb_path).unwrap();
        db.save_auto(&json_path).unwrap();
        let qdb_bytes = fs::read(&qdb_path).unwrap();
        assert!(is_qdb_bytes(&qdb_bytes));
        let json_text = fs::read_to_string(&json_path).unwrap();
        assert!(json_text.trim_start().starts_with('{'));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_byte_is_detected() {
        let dir = temp_dir("corrupt");
        let db = sample_db(2);
        let path = dir.join("db.qdb");
        db.save_qdb(&path).unwrap();
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        let flipped = dir.join("flipped.qdb");
        fs::write(&flipped, &bytes).unwrap();
        let err = EvalDatabase::load_qdb(&flipped).expect_err("corruption must be detected");
        assert_eq!(err.kind(), "parse_error");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn writer_rejects_overfill_and_underfill() {
        let dir = temp_dir("fill");
        let db = sample_db(1);
        let plan = QdbPlan::from_database(&db);
        let path = dir.join("fill.qdb");
        let mut writer = QdbWriter::create(&path, &plan).unwrap();
        let eval = sample_eval(1);
        writer.append(0, &eval).unwrap();
        let err = writer.append(0, &eval).expect_err("overfill must error");
        assert_eq!(err.kind(), "invalid_config");
        let err = writer.finish().expect_err("underfilled space 1 must fail finish");
        assert_eq!(err.kind(), "invalid_config");
        assert!(!path.exists(), "finish failure must not publish the file");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn streamed_write_matches_convert_path_bytes() {
        let dir = temp_dir("stream_eq");
        let db = sample_db(4);
        let a = dir.join("a.qdb");
        let b = dir.join("b.qdb");
        db.save_qdb(&a).unwrap();
        // Interleave appends across spaces — byte layout must not depend on
        // append order, only on (space, row) position.
        let plan = QdbPlan::from_database(&db);
        let mut writer = QdbWriter::create(&b, &plan).unwrap();
        for row in 0..4 {
            for (idx, space) in db.spaces.iter().enumerate() {
                writer.append(idx, &space.evals[row]).unwrap();
            }
        }
        writer.finish().unwrap();
        let bytes_a = fs::read(&a).unwrap();
        let bytes_b = fs::read(&b).unwrap();
        assert_eq!(bytes_a, bytes_b);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn envelope_probe_accepts_and_rejects() {
        let dir = temp_dir("envelope");
        let db = sample_db(1);
        let path = dir.join("db.qdb");
        db.save_qdb(&path).unwrap();
        let bytes = fs::read(&path).unwrap();
        check_qdb_envelope(&bytes).unwrap();
        assert!(check_qdb_envelope(b"not a qdb").is_err());
        let mut wrong_schema = bytes.clone();
        wrong_schema[8] = 99;
        let err = check_qdb_envelope(&wrong_schema).expect_err("schema must be exact");
        assert_eq!(err.kind(), "parse_error");
        let _ = fs::remove_dir_all(&dir);
    }
}
