//! Bench harness (offline `criterion` substitute).
//!
//! `cargo bench` targets use `harness = false` and drive this module:
//! warmup, timed iterations, and a statistics summary (mean/p50/p95),
//! printed in a criterion-like format plus CSV for EXPERIMENTS.md.

use std::time::Instant;

use crate::util::stats::Summary;

/// Configuration for a bench run.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Untimed warmup iterations before measurement.
    pub warmup_iters: usize,
    /// Timed iterations aggregated into the summary.
    pub measure_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup_iters: 2, measure_iters: 10 }
    }
}

impl BenchConfig {
    /// Fast config for expensive end-to-end benches.
    pub fn heavy() -> Self {
        Self { warmup_iters: 1, measure_iters: 3 }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Timing statistics over the measured iterations.
    pub summary: Summary,
}

impl BenchResult {
    /// criterion-style one-liner.
    pub fn render(&self) -> String {
        format!(
            "{:<40} time: [{} ms  {} ms  {} ms]  (mean ± σ: {} ± {} ms, n={})",
            self.name,
            fmt_ms(self.summary.min),
            fmt_ms(self.summary.p50),
            fmt_ms(self.summary.max),
            fmt_ms(self.summary.mean),
            fmt_ms(self.summary.stddev),
            self.summary.n,
        )
    }

    /// CSV row: name, mean_ms, p50_ms, p95_ms, n.
    pub fn to_csv_row(&self) -> String {
        format!(
            "{},{:.3},{:.3},{:.3},{}",
            self.name,
            self.summary.mean * 1e3,
            self.summary.p50 * 1e3,
            self.summary.p95 * 1e3,
            self.summary.n
        )
    }
}

fn fmt_ms(seconds: f64) -> String {
    format!("{:.3}", seconds * 1e3)
}

/// Time `f` under `config`, returning the timing summary (seconds).
pub fn bench_with<R>(name: &str, config: BenchConfig, mut f: impl FnMut() -> R) -> BenchResult {
    for _ in 0..config.warmup_iters {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(config.measure_iters);
    for _ in 0..config.measure_iters.max(1) {
        let start = Instant::now();
        std::hint::black_box(f());
        samples.push(start.elapsed().as_secs_f64());
    }
    let result = BenchResult { name: name.to_string(), summary: Summary::of(&samples) };
    println!("{}", result.render());
    result
}

/// [`bench_with`] under the default config.
pub fn bench<R>(name: &str, f: impl FnMut() -> R) -> BenchResult {
    bench_with(name, BenchConfig::default(), f)
}

/// Print a bench-section header (groups output in `cargo bench` logs).
pub fn section(title: &str) {
    println!("\n──── {title} ────");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let result = bench_with(
            "noop",
            BenchConfig { warmup_iters: 1, measure_iters: 5 },
            || 1 + 1,
        );
        assert_eq!(result.summary.n, 5);
        assert!(result.summary.mean >= 0.0);
    }

    #[test]
    fn render_contains_name_and_units() {
        let result = bench_with(
            "render_test",
            BenchConfig { warmup_iters: 0, measure_iters: 2 },
            || (),
        );
        let line = result.render();
        assert!(line.contains("render_test"));
        assert!(line.contains("ms"));
        let csv = result.to_csv_row();
        assert_eq!(csv.split(',').count(), 5);
    }

    #[test]
    fn timing_orders_workloads() {
        let cheap = bench_with(
            "cheap",
            BenchConfig { warmup_iters: 1, measure_iters: 3 },
            || (0..100u64).sum::<u64>(),
        );
        let costly = bench_with(
            "costly",
            BenchConfig { warmup_iters: 1, measure_iters: 3 },
            // fold with a multiply so LLVM cannot closed-form the loop
            || (0..2_000_000u64).fold(0u64, |acc, x| acc ^ x.wrapping_mul(0x9E3779B1)),
        );
        assert!(costly.summary.p50 >= cheap.summary.p50);
    }
}
